//! The discrete-event serving simulator: arrival → route → batch →
//! execute → complete, on a virtual integer-nanosecond clock, built to
//! replay tens of millions of queries.
//!
//! # Engines
//!
//! One node per hosted model. Each node's engine executes under one of
//! two models, selected by [`SimConfig::engine`] (CLI `--engine`):
//!
//! * **Lockstep** (`--engine lockstep`) — the node batches under the
//!   production size/age triggers ([`BatchWindow`], the integer-time core
//!   shared with [`Batcher`](crate::coordinator::Batcher)) and executes
//!   whole batches serially: service time = slowest member's fitted
//!   whole-query runtime, energy = sum of members' fitted energies. This
//!   is the paper's batch-32 measurement protocol, and it is the
//!   cross-check the continuous engine's totals are anchored to.
//! * **Continuous** (`--engine continuous`) — iteration-level continuous
//!   batching. The engine steps in *iterations*: each iteration runs one
//!   prefill chunk (the oldest unprefilled working-set member's whole
//!   prompt) or one decode step for the entire working set (duration =
//!   slowest member's step). Queued arrivals join the working set at
//!   iteration boundaries, up to `max_batch` slots
//!   ([`BatchWindow::slots_free`]; the age trigger does not apply —
//!   admission is greedy), and finished sequences retire immediately
//!   instead of waiting for the slowest batch member.
//!
//! Per-query phase costs come from a *calibrated split* of the fitted
//! Eq. 6–7 predictions: for zoo-known models the
//! [`perfmodel::phase::run_phase`](crate::perfmodel::run_phase) roofline
//! (prefill vs decode [`Work`](crate::perfmodel::Work) via
//! `perfmodel::flops`) supplies the prefill/decode proportions of runtime
//! and energy; for synthetic model ids the bilinear coefficients are
//! decomposed directly (`c₀·t_in` prefill vs `(c₁ + c₂·t_in)·t_out`
//! decode). The proportions rescale the fitted whole-query `r_K`/`e_K`
//! so that a sequence run end-to-end spends exactly its fitted service
//! time and energy — which is why lockstep and continuous runs agree on
//! total energy, and why batch-size-1 workloads coincide (property-tested
//! to 1e-9 in `tests/sim.rs`).
//!
//! # The zero-allocation hot path
//!
//! Steady-state simulation performs no heap allocation per event:
//!
//! * **Copy events** — heap entries are fixed-size (`t`, `seq`, node
//!   index); batch membership lives in per-node index FIFOs
//!   (`VecDeque<InFlight>`: query index + arrival time), where a batch is
//!   simply the next `size` entries — no per-batch vectors, requests, or
//!   model-id clones. The continuous engine keeps its working set in a
//!   small per-node `Vec` and reuses the same `Complete` event for
//!   iteration boundaries.
//! * **Lazy arrivals** — arrivals stream from one sorted index array
//!   instead of pre-filling the event heap with |Q| entries; the heap
//!   holds only O(nodes + in-flight batches) timeouts/completes.
//! * **Shape-memoized predictions** — the Eq. 6–7 polynomials *and* the
//!   phase split are evaluated once per (shape, model) up front via the
//!   scheduler's [`group_by_shape`] bucketing; per-iteration evaluation
//!   is a table lookup. `SimConfig::memoize = false` restores the
//!   per-member evaluation (identical results, kept for benchmarking).
//! * **Streaming metrics** — completions fold into O(1) accumulators and
//!   log-scale histograms ([`crate::stats::LogHistogram`]) — latency,
//!   queue wait, TTFT, and TPOT; per-query outcomes are retained only
//!   under [`SimConfig::per_query`].
//!
//! # Determinism contract
//!
//! The clock is a `u64` of virtual nanoseconds. Arrivals are processed in
//! (timestamp, input-index) order and win ties against timer/complete
//! events (which tie-break on creation order) — under both engines.
//! Service times and energies come from the fitted
//! [`ModelSet`](crate::models::ModelSet) predictions, arrivals from a
//! seeded [`Rng`](crate::util::Rng) — no wall-clock reads, no thread
//! scheduling, no hash-order iteration feed any decision. Equal
//! `(sets, queries, arrivals, policy, seed, config)` therefore produce
//! identical [`SimMetrics`], byte-for-byte in JSON; `tests/sim.rs` and
//! the CI `sim-smoke` step both enforce this for each engine.

use super::failure::{FailureKind, FailureScript};
use super::metrics::{MetricsRecorder, NodeStats, SimMetrics};
use super::policy::SimPolicy;
use crate::config::{lookup, swing_node, LlmSpec};
use crate::control::{CarbonConfig, CarbonMeter};
use crate::coordinator::BatchWindow;
use crate::hardware::Node as HwNode;
use crate::models::ModelSet;
use crate::perfmodel::query_phases;
use crate::scheduler::group_by_shape;
use crate::workload::Query;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Execution model of each simulated node's engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// batch-serial lockstep: a batch runs at the slowest member's fitted
    /// whole-query runtime (the paper's measurement protocol)
    #[default]
    Lockstep,
    /// iteration-level continuous batching with a prefill/decode phase
    /// split calibrated to the fitted whole-query predictions
    Continuous,
}

impl EngineKind {
    /// Artifact/CLI spelling.
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Lockstep => "lockstep",
            EngineKind::Continuous => "continuous",
        }
    }

    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "lockstep" => Some(EngineKind::Lockstep),
            "continuous" => Some(EngineKind::Continuous),
            _ => None,
        }
    }
}

/// Knobs of the simulated serving tier.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// per-node batch size trigger (lockstep) / working-set slots
    /// (continuous)
    pub max_batch: usize,
    /// per-node batch age trigger, seconds (lockstep only — continuous
    /// admission is greedy at iteration boundaries)
    pub max_wait_s: f64,
    /// latency SLO the attainment metric is measured against, seconds
    pub slo_s: f64,
    /// time-to-first-token SLO, seconds (attainment reported when set)
    pub ttft_slo_s: Option<f64>,
    /// time-per-output-token SLO, seconds (attainment reported when set)
    pub tpot_slo_s: Option<f64>,
    /// drop arrivals after this virtual time (open-ended when `None`)
    pub duration_s: Option<f64>,
    /// retain per-query [`QueryOutcome`](super::QueryOutcome)s and emit
    /// exact quantiles (`--per-query`): O(|Q|) memory, off by default
    pub per_query: bool,
    /// evaluate the fitted models once per (shape, model) instead of per
    /// batch member (identical results; `false` only for benchmarks)
    pub memoize: bool,
    /// execution model (`--engine lockstep|continuous`)
    pub engine: EngineKind,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            max_batch: 8,
            max_wait_s: 0.05,
            slo_s: 30.0,
            ttft_slo_s: None,
            tpot_slo_s: None,
            duration_s: None,
            per_query: false,
            memoize: true,
            engine: EngineKind::Lockstep,
        }
    }
}

/// Request-survival knobs (`--retry-budget`, `--breaker-threshold`,
/// `--hedge-ms`): what the serving tier does *about* failures, as
/// opposed to the [`FailureScript`] that causes them.
///
/// All three mechanisms run on the virtual clock and stay fully
/// deterministic. A simulator built without
/// [`with_resilience`](Simulator::with_resilience) is byte-identical to
/// the pre-v6 behavior (killed work requeues immediately and never
/// fails).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResilienceConfig {
    /// max re-dispatches per query after its copy dies in a kill; once
    /// exhausted the query **fails** (counted in `n_failed`, never
    /// recorded as a completion)
    pub retry_budget: u32,
    /// first retry delay, seconds; attempt `i` waits `base · 2^(i−1)`
    pub retry_base_s: f64,
    /// backoff ceiling, seconds
    pub retry_cap_s: f64,
    /// consecutive kills (without an intervening completion) that open a
    /// replica's circuit breaker; `0` disables the breaker
    pub breaker_threshold: u32,
    /// open-state duration, seconds: while open the replica is skipped
    /// whenever a sibling can take the work (it is never a black hole —
    /// if every live replica is open, routing falls through); after the
    /// cooldown the replica is half-open and one completion re-closes it
    pub breaker_cooldown_s: f64,
    /// tail hedging: duplicate a query to a second replica of its routed
    /// model once it has been in flight this long (first completion
    /// wins; the loser's energy is never charged); `None` disables
    pub hedge_after_s: Option<f64>,
}

impl Default for ResilienceConfig {
    fn default() -> ResilienceConfig {
        ResilienceConfig {
            retry_budget: 3,
            retry_base_s: 0.05,
            retry_cap_s: 1.0,
            breaker_threshold: 0,
            breaker_cooldown_s: 1.0,
            hedge_after_s: None,
        }
    }
}

/// A configured simulator: the hosted models plus run metadata recorded
/// into the metrics artifact.
pub struct Simulator<'a> {
    sets: &'a [ModelSet],
    cfg: SimConfig,
    arrival_label: String,
    seed: u64,
    zeta: f64,
    carbon: Option<CarbonConfig>,
    /// replica count per hosted model (`--replicas`); each replica is an
    /// independently batching node, arrivals go to the least-loaded up
    /// replica of the routed model
    replicas: Vec<usize>,
    /// scripted replica lifecycle events (`--failures`)
    failures: Option<&'a FailureScript>,
    /// request-survival policy (`with_resilience`); `None` = legacy
    /// immediate-requeue semantics, byte-identical to pre-v6 runs
    resilience: Option<ResilienceConfig>,
}

/// Heap events are `Copy`: batch membership lives in the node FIFOs, so
/// a completion needs only its node — the running batch (lockstep) or
/// iteration (continuous) is unique. `gen` snapshots the node's
/// completion generation at scheduling time: a kill bumps the node's
/// generation, so the aborted batch/iteration's `Complete` is discarded
/// when it surfaces (its work was requeued, not finished).
#[derive(Debug, Clone, Copy)]
enum EvKind {
    /// node's age-flush deadline fires (lockstep only)
    Timeout { node: u32 },
    /// node finishes its running batch (lockstep) / iteration (continuous)
    Complete { node: u32, gen: u32 },
    /// a killed copy's backoff elapsed: re-route the query (resilience
    /// only; the original arrival time is recovered from `arrivals_s`)
    Retry { query: u64 },
    /// the hedge deadline for a still-unanswered query: duplicate it to
    /// a second replica of its routed `model` (resilience only)
    Hedge { query: u64, model: u32 },
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    t: u64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Ev) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    /// Reversed on `(t, seq)` so `BinaryHeap` (a max-heap) pops the
    /// earliest event, FIFO among ties.
    fn cmp(&self, other: &Ev) -> Ordering {
        other.t.cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// One routed-but-uncompleted query: index into the workload (u64 so a
/// trace id space larger than u32 never truncates in the simulator) plus
/// its arrival instant, which both the age trigger and the latency
/// accounting read back.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    query: u64,
    arrive_ns: u64,
}

/// Replica-lifecycle state shared by both engines' node types. Every
/// node starts `up`; only a [`FailureScript`] changes that.
#[derive(Debug, Clone, Copy)]
struct RepState {
    /// owning hosted-model index
    model: usize,
    /// replica index within the model (0-based, model-major)
    replica: u32,
    /// dispatchable: false while down, draining, or warming up
    up: bool,
    /// a join's warm-up window is pending (rejects overlapping joins)
    joining: bool,
    /// completion generation — bumped on kill so the aborted batch or
    /// iteration's in-flight `Complete` event is discarded on arrival
    gen: u32,
    /// instant the replica last went down (kill/drain/join-create)
    down_since: Option<u64>,
    /// accumulated downtime, virtual ns
    downtime_ns: u64,
    /// circuit breaker: routing avoids this replica before this instant
    /// whenever a sibling can take the work (`0` = closed); at the
    /// instant itself the breaker is half-open — the replica is
    /// routable again and one completion re-closes it
    breaker_until: u64,
    /// kills since the last completion (the breaker's trip counter)
    consec_fails: u32,
}

impl RepState {
    fn new(model: usize, replica: u32) -> RepState {
        RepState {
            model,
            replica,
            up: true,
            joining: false,
            gen: 0,
            down_since: None,
            downtime_ns: 0,
            breaker_until: 0,
            consec_fails: 0,
        }
    }

    /// A freshly created join target: down and warming up from `t`.
    fn joining(model: usize, replica: u32, t: u64) -> RepState {
        RepState {
            up: false,
            joining: true,
            down_since: Some(t),
            ..RepState::new(model, replica)
        }
    }

    /// Routable under the breaker at `t` (closed, or half-open probe).
    fn breaker_ok(&self, t: u64) -> bool {
        t >= self.breaker_until
    }

    /// Account one kill against the breaker; `true` when it trips open.
    fn breaker_note_kill(&mut self, t: u64, rc: &ResilienceConfig) -> bool {
        if rc.breaker_threshold == 0 {
            return false;
        }
        self.consec_fails += 1;
        if self.consec_fails >= rc.breaker_threshold {
            self.breaker_until = t.saturating_add(to_ns(rc.breaker_cooldown_s));
            self.consec_fails = 0;
            return true;
        }
        false
    }

    /// A completion closes the breaker and clears the trip counter.
    fn breaker_note_success(&mut self) {
        self.consec_fails = 0;
        self.breaker_until = 0;
    }

    /// Close the open downtime interval at `t` (activation or end of run).
    fn settle_downtime(&mut self, t: u64) {
        if let Some(s) = self.down_since.take() {
            self.downtime_ns += t.saturating_sub(s);
        }
    }

    /// Fold lifecycle accounting into the node's stats row.
    fn finalize(mut self, t_last: u64, stats: &mut NodeStats) {
        self.settle_downtime(t_last);
        stats.replica = self.replica;
        stats.downtime_s = self.downtime_ns as f64 / 1e9;
    }
}

/// A [`FailureEvent`] translated onto the virtual clock. A join expands
/// into `Create` (node exists, warming up) at its event time plus
/// `Activate` (dispatchable, parked work flushed) after the warm-up.
#[derive(Debug, Clone, Copy, PartialEq)]
enum FailAction {
    Kill,
    Drain,
    Create,
    Activate,
}

#[derive(Debug, Clone, Copy)]
struct FailEv {
    t: u64,
    model: usize,
    replica: usize,
    action: FailAction,
}

/// What becomes of a copy orphaned by a kill under resilience.
enum OrphanFate {
    /// schedule an [`EvKind::Retry`] this far in the future
    Retry { delay_ns: u64 },
    /// the copy dies: budget exhausted, or a hedge twin already answered
    Dropped,
}

/// Per-run request-survival bookkeeping, shared by both engines. A
/// query has one *copy* in flight normally, two once hedged; copies die
/// in kills (budget exhausted) or at completion, and the query fails
/// only when its last copy dies unanswered.
struct Survival {
    cfg: ResilienceConfig,
    /// kills absorbed so far, per query (the retry budget's counter)
    attempts: Vec<u32>,
    /// live copies per query (queued, running, parked, or pending retry)
    copies: Vec<u8>,
    /// first completion already recorded (later copies are losers)
    recorded: Vec<bool>,
    /// every copy died with the budget exhausted — counted in `n_failed`
    failed: Vec<bool>,
    /// node currently holding the query's primary copy (`u32::MAX` =
    /// parked); the hedge duplicates to a *different* replica
    holder: Vec<u32>,
    n_failed: u64,
}

impl Survival {
    fn new(cfg: ResilienceConfig, n_queries: usize) -> Survival {
        Survival {
            cfg,
            attempts: vec![0; n_queries],
            copies: vec![0; n_queries],
            recorded: vec![false; n_queries],
            failed: vec![false; n_queries],
            holder: vec![u32::MAX; n_queries],
            n_failed: 0,
        }
    }

    /// A copy of `qi` was placed on node `j`.
    fn placed(&mut self, qi: usize, j: usize) {
        self.holder[qi] = j as u32;
    }

    /// A copy of `qi` parked (no live replica to take it).
    fn parked(&mut self, qi: usize) {
        self.holder[qi] = u32::MAX;
    }

    /// A kill orphaned one copy of `qi`: retry it (capped exponential
    /// backoff) or drop it, failing the query when it was the last copy.
    fn orphaned(&mut self, qi: usize) -> OrphanFate {
        if self.recorded[qi] {
            // A hedge twin already answered; the loser just vanishes.
            self.copies[qi] = self.copies[qi].saturating_sub(1);
            return OrphanFate::Dropped;
        }
        self.attempts[qi] += 1;
        if self.attempts[qi] <= self.cfg.retry_budget {
            let backoff = (self.cfg.retry_base_s
                * 2f64.powi(self.attempts[qi] as i32 - 1))
            .min(self.cfg.retry_cap_s);
            OrphanFate::Retry {
                delay_ns: to_ns(backoff),
            }
        } else {
            self.copies[qi] = self.copies[qi].saturating_sub(1);
            if self.copies[qi] == 0 {
                self.failed[qi] = true;
                self.n_failed += 1;
            }
            OrphanFate::Dropped
        }
    }

    /// A copy of `qi` reached completion; `true` iff it is the first
    /// (record it — later finishers are hedge losers and stay unpaid).
    fn completed(&mut self, qi: usize) -> bool {
        self.copies[qi] = self.copies[qi].saturating_sub(1);
        if self.recorded[qi] {
            false
        } else {
            self.recorded[qi] = true;
            true
        }
    }
}

/// Per-node state (lockstep engine). The FIFO holds, front to back: the
/// running batch (first `running` entries), flushed ready batches
/// (`ready` holds their sizes), then the accumulating batcher tail
/// (`pending` entries).
struct Node {
    fifo: VecDeque<InFlight>,
    running: usize,
    running_start: u64,
    ready: VecDeque<usize>,
    pending: usize,
    /// dedupes Timeout events: only the one matching this value acts
    next_timeout: Option<u64>,
    rep: RepState,
    stats: NodeStats,
}

/// One working-set member of a continuous-batching node.
#[derive(Debug, Clone, Copy)]
struct ActiveSeq {
    query: u64,
    arrive_ns: u64,
    /// admission into the working set (queue wait ends here)
    start_ns: u64,
    /// completion of the first decode step (token 1); `u64::MAX` = not
    /// yet emitted
    first_token_ns: u64,
    prefilled: bool,
    steps_left: u32,
}

/// What a continuous-batching node's running iteration is doing.
#[derive(Debug, Clone, Copy)]
enum IterKind {
    /// prefilling working-set member `member`'s whole prompt
    Prefill { member: usize },
    /// one decode step for every working-set member
    Decode,
}

/// Per-node state (continuous engine): an admission queue plus the
/// resident working set, stepped one iteration at a time.
struct CNode {
    queue: VecDeque<InFlight>,
    active: Vec<ActiveSeq>,
    iter: Option<IterKind>,
    iter_start: u64,
    rep: RepState,
    stats: NodeStats,
}

/// Seconds → virtual nanoseconds (round to nearest).
fn to_ns(s: f64) -> u64 {
    (s * 1e9).round() as u64
}

/// Calibrated per-(model, shape) phase split: the fitted whole-query
/// service time and energy, apportioned between one prefill chunk and
/// `t_out` decode steps.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PhaseEntry {
    /// prefill chunk duration, virtual ns
    pub(crate) prefill_ns: u64,
    /// one decode step, virtual ns
    pub(crate) step_ns: u64,
    /// prefill's share of the fitted whole-query energy, J
    pub(crate) prefill_j: f64,
}

/// Prefill's share of a two-phase total, clamped to [0, 1]; degenerate
/// splits (both phases zero) fall back to an even split.
fn phase_frac(prefill: f64, decode: f64) -> f64 {
    let f = prefill / (prefill + decode);
    if f.is_finite() {
        f.clamp(0.0, 1.0)
    } else {
        0.5
    }
}

/// Per-set phase-split source. Models the zoo knows
/// ([`crate::config::lookup`]) go through the §Perf roofline
/// ([`query_phases`]: prefill vs mean-context decode `Work` on the Swing
/// node at the model's native TP degree); synthetic/unknown ids decompose
/// the fitted bilinear polynomials instead (`c₀·t_in` prefill weight vs
/// `(c₁ + c₂·t_in)·t_out` decode weight, for runtime and energy alike).
pub(crate) struct PhaseSplitter {
    node: HwNode,
    specs: Vec<Option<LlmSpec>>,
}

impl PhaseSplitter {
    pub(crate) fn new(sets: &[ModelSet]) -> PhaseSplitter {
        PhaseSplitter {
            node: HwNode::new(swing_node()),
            specs: sets.iter().map(|s| lookup(&s.model_id)).collect(),
        }
    }

    /// (prefill share of runtime, prefill share of energy), both in [0, 1].
    fn fracs(&self, set: &ModelSet, k: usize, t_in: u32, t_out: u32) -> (f64, f64) {
        match &self.specs[k] {
            Some(spec) => {
                let ph = query_phases(spec, &self.node, t_in, t_out);
                (
                    phase_frac(ph.prefill_s, t_out as f64 * ph.decode_step_s),
                    phase_frac(ph.prefill_j, ph.decode_j),
                )
            }
            None => {
                let (ti, to) = (t_in as f64, t_out as f64);
                let [r0, r1, r2] = set.runtime.coefs;
                let [e0, e1, e2] = set.energy.coefs;
                (
                    phase_frac(r0 * ti, (r1 + r2 * ti) * to),
                    phase_frac(e0 * ti, (e1 + e2 * ti) * to),
                )
            }
        }
    }

    /// The calibrated split for one query shape on model `k`: proportions
    /// from the phase model, totals from the fitted predictions — so
    /// `prefill_ns + t_out·step_ns` reproduces the fitted service time
    /// (to rounding) and `prefill_j ≤` the fitted energy always.
    pub(crate) fn entry(&self, set: &ModelSet, k: usize, t_in: u32, t_out: u32) -> PhaseEntry {
        let (ti, to) = (t_in as f64, t_out as f64);
        let service_s = set.runtime.predict(ti, to).max(0.0);
        let energy_j = set.energy.predict(ti, to);
        let (tf, ef) = self.fracs(set, k, t_in, t_out);
        PhaseEntry {
            prefill_ns: to_ns(service_s * tf),
            step_ns: to_ns(service_s * (1.0 - tf) / to.max(1.0)),
            prefill_j: energy_j * ef,
        }
    }
}

/// Per-(shape, model) prediction tables: `tab[k * n_shapes + shape]`.
/// A memo is a pure function of `(sets, queries)`, so the comparison
/// harness builds it once and shares it across every (policy, seed) run
/// instead of re-bucketing per task.
pub(crate) struct Memo {
    n_shapes: usize,
    shape_of: Vec<usize>,
    service_ns: Vec<u64>,
    energy_j: Vec<f64>,
    prefill_ns: Vec<u64>,
    step_ns: Vec<u64>,
    prefill_j: Vec<f64>,
}

impl Memo {
    /// One polynomial evaluation + one phase split per (shape, model);
    /// per-member evaluation becomes a table lookup.
    pub(crate) fn build(sets: &[ModelSet], queries: &[Query]) -> Memo {
        let splitter = PhaseSplitter::new(sets);
        let groups = group_by_shape(queries);
        let s = groups.n_shapes();
        let mut service_ns = vec![0u64; s * sets.len()];
        let mut energy_j = vec![0.0f64; s * sets.len()];
        let mut prefill_ns = vec![0u64; s * sets.len()];
        let mut step_ns = vec![0u64; s * sets.len()];
        let mut prefill_j = vec![0.0f64; s * sets.len()];
        for (k, set) in sets.iter().enumerate() {
            for (si, sh) in groups.shapes.iter().enumerate() {
                let (ti, to) = (sh.t_in as f64, sh.t_out as f64);
                service_ns[k * s + si] = to_ns(set.runtime.predict(ti, to).max(0.0));
                energy_j[k * s + si] = set.energy.predict(ti, to);
                let e = splitter.entry(set, k, sh.t_in, sh.t_out);
                prefill_ns[k * s + si] = e.prefill_ns;
                step_ns[k * s + si] = e.step_ns;
                prefill_j[k * s + si] = e.prefill_j;
            }
        }
        Memo {
            n_shapes: s,
            shape_of: groups.shape_of,
            service_ns,
            energy_j,
            prefill_ns,
            step_ns,
            prefill_j,
        }
    }
}

impl<'a> Simulator<'a> {
    pub fn new(sets: &'a [ModelSet], cfg: SimConfig) -> Simulator<'a> {
        assert!(!sets.is_empty(), "simulator needs at least one model");
        assert!(cfg.max_batch > 0, "max_batch must be positive");
        assert!(
            cfg.max_wait_s.is_finite() && (0.0..=1e9).contains(&cfg.max_wait_s),
            "max_wait_s must be finite and in [0, 1e9]"
        );
        Simulator {
            replicas: vec![1; sets.len()],
            sets,
            cfg,
            arrival_label: "trace".to_string(),
            seed: 0,
            zeta: 0.5,
            carbon: None,
            failures: None,
            resilience: None,
        }
    }

    /// Host each model on `counts[k]` replica nodes instead of one.
    /// Replicas batch independently; arrivals routed to model `k` are
    /// dispatched to its least-loaded up replica (lowest index on ties).
    /// `[1, 1, …]` is byte-identical to the unreplicated simulator.
    pub fn with_replicas(mut self, counts: &[usize]) -> anyhow::Result<Simulator<'a>> {
        if counts.len() != self.sets.len() {
            anyhow::bail!(
                "replica counts for {} models but {} are hosted",
                counts.len(),
                self.sets.len()
            );
        }
        if let Some(k) = counts.iter().position(|&r| r == 0) {
            anyhow::bail!("model {k} needs at least one replica");
        }
        self.replicas = counts.to_vec();
        Ok(self)
    }

    /// Inject a scripted failure/elasticity scenario ([`FailureScript`]):
    /// replica kills (in-flight work requeued), drains, and warm-up joins
    /// replayed deterministically on the virtual clock. The script label
    /// is recorded as the artifact's `scenario`.
    pub fn with_failures(mut self, script: &'a FailureScript) -> Simulator<'a> {
        self.failures = Some(script);
        self
    }

    /// Turn on request-level survival ([`ResilienceConfig`]): retry with
    /// capped exponential backoff and a budget, a per-replica circuit
    /// breaker, and optional tail hedging — all on the virtual clock.
    /// Changes kill semantics: orphaned work waits out a backoff instead
    /// of requeueing instantly, and queries whose budget runs out *fail*
    /// (`n_failed`) instead of blocking the run.
    pub fn with_resilience(mut self, rc: ResilienceConfig) -> anyhow::Result<Simulator<'a>> {
        if !(rc.retry_base_s.is_finite() && rc.retry_base_s > 0.0) {
            anyhow::bail!("retry_base_s must be finite and positive, got {}", rc.retry_base_s);
        }
        if !(rc.retry_cap_s.is_finite() && rc.retry_cap_s >= rc.retry_base_s) {
            anyhow::bail!(
                "retry_cap_s must be finite and >= retry_base_s ({}), got {}",
                rc.retry_base_s,
                rc.retry_cap_s
            );
        }
        if !(rc.breaker_cooldown_s.is_finite() && rc.breaker_cooldown_s > 0.0) {
            anyhow::bail!(
                "breaker_cooldown_s must be finite and positive, got {}",
                rc.breaker_cooldown_s
            );
        }
        if let Some(h) = rc.hedge_after_s {
            if !(h.is_finite() && h > 0.0) {
                anyhow::bail!("hedge_after_s must be finite and positive, got {h}");
            }
        }
        self.resilience = Some(rc);
        Ok(self)
    }

    /// Record run metadata (arrival process label, seed, ζ) into the
    /// produced artifact.
    pub fn labeled(mut self, arrival: &str, seed: u64, zeta: f64) -> Simulator<'a> {
        self.arrival_label = arrival.to_string();
        self.seed = seed;
        self.zeta = zeta;
        self
    }

    /// Meter realized grams-CO₂ per carbon window: each completion's
    /// predicted energy is converted at the grid intensity of its virtual
    /// completion instant ([`CarbonMeter`]), and the per-window totals
    /// land in the metrics artifact. Simulator-owned so every compared
    /// policy is accounted under the identical signal.
    pub fn with_carbon(mut self, cfg: CarbonConfig) -> Simulator<'a> {
        self.carbon = Some(cfg);
        self
    }

    /// Translate the failure script onto the virtual clock: joins expand
    /// into a `Create` at the event time plus an `Activate` after the
    /// warm-up, then everything is stably time-sorted (so equal-time
    /// events keep script order, and an activate never precedes its
    /// create).
    fn fail_events(&self) -> anyhow::Result<Vec<FailEv>> {
        let mut evs = Vec::new();
        let Some(script) = self.failures else {
            return Ok(evs);
        };
        for ev in script.events() {
            if ev.model >= self.sets.len() {
                anyhow::bail!(
                    "failure script targets model {} but only {} are hosted",
                    ev.model,
                    self.sets.len()
                );
            }
            let t = to_ns(ev.t_s);
            let (model, replica) = (ev.model, ev.replica);
            match ev.kind {
                FailureKind::Kill => evs.push(FailEv {
                    t,
                    model,
                    replica,
                    action: FailAction::Kill,
                }),
                FailureKind::Drain => evs.push(FailEv {
                    t,
                    model,
                    replica,
                    action: FailAction::Drain,
                }),
                FailureKind::Join { warmup_s } => {
                    evs.push(FailEv {
                        t,
                        model,
                        replica,
                        action: FailAction::Create,
                    });
                    evs.push(FailEv {
                        t: t.saturating_add(to_ns(warmup_s)),
                        model,
                        replica,
                        action: FailAction::Activate,
                    });
                }
            }
        }
        evs.sort_by_key(|e| e.t);
        Ok(evs)
    }

    /// Replay `queries` arriving at `arrivals_s` (seconds, parallel to
    /// `queries`, any order) through `policy` on the simulated cluster.
    pub fn run(
        &self,
        queries: &[Query],
        arrivals_s: &[f64],
        policy: &mut SimPolicy,
    ) -> anyhow::Result<SimMetrics> {
        let memo = self.cfg.memoize.then(|| Memo::build(self.sets, queries));
        self.run_with_memo(queries, arrivals_s, policy, memo.as_ref())
    }

    /// [`run`](Simulator::run) with a caller-supplied prediction memo,
    /// which MUST have been built from the same `(sets, queries)` (the
    /// comparison harness shares one memo across its whole policy×seed
    /// grid). `None` evaluates the fitted models per batch member.
    pub(crate) fn run_with_memo(
        &self,
        queries: &[Query],
        arrivals_s: &[f64],
        policy: &mut SimPolicy,
        memo: Option<&Memo>,
    ) -> anyhow::Result<SimMetrics> {
        if let Some(m) = memo {
            debug_assert_eq!(m.shape_of.len(), queries.len(), "memo/queries mismatch");
        }
        if queries.len() != arrivals_s.len() {
            anyhow::bail!(
                "{} queries but {} arrival times",
                queries.len(),
                arrivals_s.len()
            );
        }
        if let Some(bad) = arrivals_s.iter().find(|t| !t.is_finite() || **t < 0.0) {
            anyhow::bail!("arrival times must be finite and >= 0, got {bad}");
        }

        // Arrivals in (time, input index) order. The sorted index array
        // *is* the arrival stream: arrivals never enter the event heap.
        let mut order: Vec<u64> = (0..queries.len() as u64).collect();
        order.sort_by(|&a, &b| {
            arrivals_s[a as usize]
                .partial_cmp(&arrivals_s[b as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        // The duration cap drops the (sorted) suffix of late arrivals.
        let admitted = match self.cfg.duration_s.map(to_ns) {
            Some(h) => order.partition_point(|&qi| to_ns(arrivals_s[qi as usize]) <= h),
            None => order.len(),
        };
        let n_dropped = order.len() - admitted;
        // The virtual clock caps at 1e9 s (≈ 31 years, far inside u64
        // nanoseconds). Later arrivals are fine only when the duration
        // cap already dropped them — so bound just the admitted suffix.
        if admitted > 0 {
            let last = arrivals_s[order[admitted - 1] as usize];
            if last > 1e9 {
                anyhow::bail!(
                    "arrival times inside the simulated window must be <= 1e9 s, got {last} \
                     (use --duration to cap the run)"
                );
            }
        }

        // Shape-memoized predictions: table lookups per batch member when
        // a memo is present, direct polynomial evaluation otherwise. The
        // memo-less phase path evaluates through an identical
        // `PhaseSplitter::entry`, so memoization never changes a result.
        let splitter = match memo {
            Some(_) => None,
            None => Some(PhaseSplitter::new(self.sets)),
        };
        let service_ns_of = |k: usize, qi: usize| -> u64 {
            match memo {
                Some(m) => m.service_ns[k * m.n_shapes + m.shape_of[qi]],
                None => {
                    let q = &queries[qi];
                    to_ns(
                        self.sets[k]
                            .runtime
                            .predict(q.t_in as f64, q.t_out as f64)
                            .max(0.0),
                    )
                }
            }
        };
        let energy_of = |k: usize, qi: usize| -> f64 {
            match memo {
                Some(m) => m.energy_j[k * m.n_shapes + m.shape_of[qi]],
                None => {
                    let q = &queries[qi];
                    self.sets[k].energy.predict(q.t_in as f64, q.t_out as f64)
                }
            }
        };
        let phase_of = |k: usize, qi: usize| -> PhaseEntry {
            match memo {
                Some(m) => {
                    let i = k * m.n_shapes + m.shape_of[qi];
                    PhaseEntry {
                        prefill_ns: m.prefill_ns[i],
                        step_ns: m.step_ns[i],
                        prefill_j: m.prefill_j[i],
                    }
                }
                None => {
                    let q = &queries[qi];
                    splitter
                        .as_ref()
                        .expect("splitter present when memo absent")
                        .entry(&self.sets[k], k, q.t_in, q.t_out)
                }
            }
        };

        let window = BatchWindow {
            max_batch: self.cfg.max_batch,
            max_wait_ns: to_ns(self.cfg.max_wait_s),
        };
        let mut recorder = MetricsRecorder::new(
            self.cfg.slo_s,
            self.cfg.ttft_slo_s,
            self.cfg.tpot_slo_s,
            self.cfg.per_query,
        );
        let mut meter = self.carbon.as_ref().map(CarbonMeter::new);

        // The scripted outage, translated onto the virtual clock. The
        // initial capacity push is a no-op for uniform single-replica
        // fleets, preserving byte-identity with pre-cluster runs.
        let fails = self.fail_events()?;
        for (k, &r) in self.replicas.iter().enumerate() {
            policy.on_capacity(k, r)?;
        }

        let (stats, n_failed) = match self.cfg.engine {
            EngineKind::Lockstep => self.run_lockstep(
                queries,
                arrivals_s,
                policy,
                &order,
                admitted,
                &fails,
                window,
                &service_ns_of,
                &energy_of,
                &phase_of,
                &mut recorder,
                &mut meter,
            )?,
            EngineKind::Continuous => self.run_continuous(
                queries,
                arrivals_s,
                policy,
                &order,
                admitted,
                &fails,
                window,
                &energy_of,
                &phase_of,
                &mut recorder,
                &mut meter,
            )?,
        };

        // Conservation invariant: every admitted arrival either
        // completed (requeued work included) or exhausted its retry
        // budget; a query parked forever (every replica of its model
        // down at end of run) trips this.
        if recorder.n() + n_failed != admitted as u64 {
            anyhow::bail!(
                "simulator lost queries: {} admitted, {} completed, {} failed \
                 (a failure script must leave each model a live replica to flush parked work)",
                admitted,
                recorder.n(),
                n_failed
            );
        }

        let scenario = match self.failures {
            Some(s) if !s.is_empty() => s.label(),
            _ => "none".to_string(),
        };
        let n_requeued = stats.iter().map(|s| s.requeued).sum();
        let mut m = recorder.finish(
            policy.kind().label().to_string(),
            self.cfg.engine.label().to_string(),
            scenario,
            self.arrival_label.clone(),
            self.seed,
            self.zeta,
            n_dropped as u64,
            n_requeued,
            n_failed,
            policy.plan_stats(),
            stats,
        );
        m.replan_stats = policy.replan_stats();
        m.zeta_trajectory = policy.zeta_trajectory();
        m.carbon = meter.map(CarbonMeter::report);
        Ok(m)
    }

    /// Batch-serial lockstep event loop (the PR 4/5 engine). First-token
    /// instants are synthesized *as if* each member streamed its own
    /// prefill + first decode step from batch start — so TTFT/TPOT are
    /// comparable across engines and the lockstep numbers still expose
    /// the batch-formation wait the continuous engine eliminates.
    #[allow(clippy::too_many_arguments)]
    fn run_lockstep(
        &self,
        queries: &[Query],
        arrivals_s: &[f64],
        policy: &mut SimPolicy,
        order: &[u64],
        admitted: usize,
        fails: &[FailEv],
        window: BatchWindow,
        service_ns_of: &dyn Fn(usize, usize) -> u64,
        energy_of: &dyn Fn(usize, usize) -> f64,
        phase_of: &dyn Fn(usize, usize) -> PhaseEntry,
        recorder: &mut MetricsRecorder,
        meter: &mut Option<CarbonMeter>,
    ) -> anyhow::Result<(Vec<NodeStats>, u64)> {
        // Flat replica fleet, model-major; `model_nodes[k]` indexes model
        // k's replicas (joins append), `parked[k]` holds work routed to k
        // while none of its replicas is up.
        let mut surv = self.resilience.map(|rc| Survival::new(rc, queries.len()));
        let mut nodes: Vec<Node> = Vec::new();
        let mut model_nodes: Vec<Vec<usize>> = Vec::with_capacity(self.sets.len());
        for (k, s) in self.sets.iter().enumerate() {
            let mut idxs = Vec::with_capacity(self.replicas[k]);
            for r in 0..self.replicas[k] {
                idxs.push(nodes.len());
                nodes.push(Node {
                    fifo: VecDeque::new(),
                    running: 0,
                    running_start: 0,
                    ready: VecDeque::new(),
                    pending: 0,
                    next_timeout: None,
                    rep: RepState::new(k, r as u32),
                    stats: NodeStats {
                        model_id: s.model_id.clone(),
                        ..NodeStats::default()
                    },
                });
            }
            model_nodes.push(idxs);
        }
        let mut parked: Vec<VecDeque<InFlight>> = vec![VecDeque::new(); self.sets.len()];

        let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
        let mut seq = 0u64;

        // Start the next ready batch on an idle node: service time is the
        // slowest member's predicted runtime (lockstep batch execution).
        let try_start =
            |j: usize, t: u64, nodes: &mut Vec<Node>, heap: &mut BinaryHeap<Ev>, seq: &mut u64| {
                let node = &mut nodes[j];
                if node.running > 0 {
                    return;
                }
                let Some(size) = node.ready.pop_front() else {
                    return;
                };
                let k = node.rep.model;
                let mut service = 0u64;
                for member in node.fifo.iter().take(size) {
                    service = service.max(service_ns_of(k, member.query as usize));
                }
                node.running = size;
                node.running_start = t;
                heap.push(Ev {
                    t: t.saturating_add(service),
                    seq: *seq,
                    kind: EvKind::Complete {
                        node: j as u32,
                        gen: node.rep.gen,
                    },
                });
                *seq += 1;
            };

        // Arm (or refresh) the node's age-flush wakeup at the window
        // deadline of its oldest pending entry.
        let schedule_timeout =
            |j: usize, nodes: &mut Vec<Node>, heap: &mut BinaryHeap<Ev>, seq: &mut u64| {
                let node = &mut nodes[j];
                if node.pending == 0 {
                    return;
                }
                let oldest = node.fifo[node.fifo.len() - node.pending].arrive_ns;
                let dl = window.deadline(oldest);
                if node.next_timeout != Some(dl) {
                    node.next_timeout = Some(dl);
                    heap.push(Ev {
                        t: dl,
                        seq: *seq,
                        kind: EvKind::Timeout { node: j as u32 },
                    });
                    *seq += 1;
                }
            };

        // Least-loaded up replica of model `k` (FIFO depth, lowest index
        // on ties); `None` while the whole fleet is down. An open circuit
        // breaker diverts traffic only while a breaker-closed sibling
        // exists — it never blackholes the model (parked work can only be
        // flushed by an Activate, so a hard block would strand queries).
        let pick = |k: usize,
                    t: u64,
                    nodes: &Vec<Node>,
                    model_nodes: &[Vec<usize>]|
         -> Option<usize> {
            let mut best: Option<usize> = None;
            let mut best_any: Option<usize> = None;
            for &j in &model_nodes[k] {
                if !nodes[j].rep.up {
                    continue;
                }
                if best_any.map_or(true, |b| nodes[j].fifo.len() < nodes[b].fifo.len()) {
                    best_any = Some(j);
                }
                if nodes[j].rep.breaker_ok(t)
                    && best.map_or(true, |b| nodes[j].fifo.len() < nodes[b].fifo.len())
                {
                    best = Some(j);
                }
            }
            best.or(best_any)
        };

        // Put one query on node `j` and run the batcher triggers.
        let place = |j: usize,
                     f: InFlight,
                     t: u64,
                     nodes: &mut Vec<Node>,
                     heap: &mut BinaryHeap<Ev>,
                     seq: &mut u64| {
            let node = &mut nodes[j];
            node.fifo.push_back(f);
            node.pending += 1;
            if window.filled(node.pending) {
                let size = node.pending;
                node.pending = 0;
                node.ready.push_back(size);
                try_start(j, t, nodes, heap, seq);
            } else {
                schedule_timeout(j, nodes, heap, seq);
            }
        };

        // Hand one query (a fresh arrival, a kill's requeue, a retry, or
        // a parked flush — arrival time preserved throughout) to model
        // `k`.
        let enqueue = |k: usize,
                       f: InFlight,
                       t: u64,
                       nodes: &mut Vec<Node>,
                       model_nodes: &[Vec<usize>],
                       parked: &mut Vec<VecDeque<InFlight>>,
                       heap: &mut BinaryHeap<Ev>,
                       seq: &mut u64,
                       surv: &mut Option<Survival>| {
            match pick(k, t, nodes, model_nodes) {
                Some(j) => {
                    if let Some(s) = surv.as_mut() {
                        s.placed(f.query as usize, j);
                    }
                    place(j, f, t, nodes, heap, seq);
                }
                None => {
                    if let Some(s) = surv.as_mut() {
                        s.parked(f.query as usize);
                    }
                    parked[k].push_back(f);
                }
            }
        };

        let mut next_arrival = 0usize;
        let mut next_fail = 0usize;
        let mut t_last = 0u64;
        loop {
            // Event-time ties resolve failures < arrivals < engine
            // events, so an arrival at the kill instant already sees the
            // shrunken fleet — part of the determinism contract.
            let arrival_t = (next_arrival < admitted)
                .then(|| to_ns(arrivals_s[order[next_arrival] as usize]));
            let fail_t = (next_fail < fails.len()).then(|| fails[next_fail].t);
            let take_fail = match fail_t {
                Some(tf) => {
                    arrival_t.map_or(true, |ta| tf <= ta)
                        && heap.peek().map_or(true, |ev| tf <= ev.t)
                }
                None => false,
            };
            if take_fail {
                let fe = fails[next_fail];
                next_fail += 1;
                let (t, k, r) = (fe.t, fe.model, fe.replica);
                t_last = t_last.max(t);
                match fe.action {
                    FailAction::Kill | FailAction::Drain => {
                        let verb = if fe.action == FailAction::Kill {
                            "kill"
                        } else {
                            "drain"
                        };
                        let Some(&j) = model_nodes[k].get(r) else {
                            anyhow::bail!(
                                "failure script: {verb} targets model {k} replica {r} but only \
                                 {} exist",
                                model_nodes[k].len()
                            );
                        };
                        if !nodes[j].rep.up {
                            anyhow::bail!(
                                "failure script: {verb} of model {k} replica {r} at t={:.3}s \
                                 but it is already down",
                                t as f64 / 1e9
                            );
                        }
                        nodes[j].rep.up = false;
                        nodes[j].rep.down_since = Some(t);
                        nodes[j].next_timeout = None;
                        if fe.action == FailAction::Kill {
                            // Abrupt loss: abort the running batch (its
                            // Complete is now stale by generation) and
                            // requeue everything, arrival times intact.
                            // Aborted work consumed no energy/busy time.
                            nodes[j].rep.gen += 1;
                            nodes[j].running = 0;
                            nodes[j].ready.clear();
                            nodes[j].pending = 0;
                            let orphans: Vec<InFlight> = nodes[j].fifo.drain(..).collect();
                            nodes[j].stats.requeued += orphans.len() as u64;
                            if let Some(rc) = self.resilience.as_ref() {
                                if nodes[j].rep.breaker_note_kill(t, rc) {
                                    nodes[j].stats.breaker_trips += 1;
                                }
                            }
                            if surv.is_some() {
                                // Resilience: orphans wait out a backoff
                                // (or die once the budget is spent)
                                // instead of requeueing instantly.
                                let s = surv.as_mut().expect("checked above");
                                for f in orphans {
                                    match s.orphaned(f.query as usize) {
                                        OrphanFate::Retry { delay_ns } => {
                                            nodes[j].stats.retries += 1;
                                            heap.push(Ev {
                                                t: t.saturating_add(delay_ns),
                                                seq,
                                                kind: EvKind::Retry { query: f.query },
                                            });
                                            seq += 1;
                                        }
                                        OrphanFate::Dropped => {}
                                    }
                                }
                            } else {
                                for f in orphans {
                                    enqueue(
                                        k, f, t, &mut nodes, &model_nodes, &mut parked,
                                        &mut heap, &mut seq, &mut surv,
                                    );
                                }
                            }
                        } else {
                            // Graceful leave: flush the batcher tail and
                            // let everything already queued finish.
                            if nodes[j].pending > 0 {
                                let size = nodes[j].pending;
                                nodes[j].pending = 0;
                                nodes[j].ready.push_back(size);
                            }
                            try_start(j, t, &mut nodes, &mut heap, &mut seq);
                        }
                    }
                    FailAction::Create => {
                        let fleet = model_nodes[k].len();
                        if r < fleet {
                            let j = model_nodes[k][r];
                            if nodes[j].rep.up {
                                anyhow::bail!(
                                    "failure script: join targets model {k} replica {r} at \
                                     t={:.3}s but it is up",
                                    t as f64 / 1e9
                                );
                            }
                            if nodes[j].rep.joining {
                                anyhow::bail!(
                                    "failure script: overlapping joins for model {k} replica {r}"
                                );
                            }
                            nodes[j].rep.joining = true;
                        } else if r == fleet {
                            let j = nodes.len();
                            nodes.push(Node {
                                fifo: VecDeque::new(),
                                running: 0,
                                running_start: 0,
                                ready: VecDeque::new(),
                                pending: 0,
                                next_timeout: None,
                                rep: RepState::joining(k, r as u32, t),
                                stats: NodeStats {
                                    model_id: self.sets[k].model_id.clone(),
                                    ..NodeStats::default()
                                },
                            });
                            model_nodes[k].push(j);
                        } else {
                            anyhow::bail!(
                                "failure script: join targets model {k} replica {r} but only \
                                 {fleet} exist (replica indices are contiguous)"
                            );
                        }
                    }
                    FailAction::Activate => {
                        let j = model_nodes[k][r];
                        debug_assert!(nodes[j].rep.joining, "Activate without its Create");
                        nodes[j].rep.joining = false;
                        nodes[j].rep.up = true;
                        nodes[j].rep.settle_downtime(t);
                        // Flush work parked while the fleet was dark.
                        let flushed: Vec<InFlight> = parked[k].drain(..).collect();
                        for f in flushed {
                            enqueue(
                                k, f, t, &mut nodes, &model_nodes, &mut parked, &mut heap,
                                &mut seq, &mut surv,
                            );
                        }
                    }
                }
                let up = model_nodes[k].iter().filter(|&&j| nodes[j].rep.up).count();
                policy.on_capacity(k, up)?;
                continue;
            }
            let take_arrival = match (arrival_t, heap.peek()) {
                (Some(ta), Some(ev)) => ta <= ev.t,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            if take_arrival {
                let qi = order[next_arrival] as usize;
                next_arrival += 1;
                let t = arrival_t.unwrap();
                t_last = t_last.max(t);
                let k = policy.route_at(t, &queries[qi])?;
                debug_assert!(k < self.sets.len());
                if let Some(s) = surv.as_mut() {
                    s.copies[qi] = 1;
                    if let Some(h) = s.cfg.hedge_after_s {
                        heap.push(Ev {
                            t: t.saturating_add(to_ns(h)),
                            seq,
                            kind: EvKind::Hedge {
                                query: qi as u64,
                                model: k as u32,
                            },
                        });
                        seq += 1;
                    }
                }
                enqueue(
                    k,
                    InFlight {
                        query: qi as u64,
                        arrive_ns: t,
                    },
                    t,
                    &mut nodes,
                    &model_nodes,
                    &mut parked,
                    &mut heap,
                    &mut seq,
                    &mut surv,
                );
                continue;
            }
            let Ev { t, kind, .. } = heap.pop().unwrap();
            t_last = t_last.max(t);
            // Controller hook: time-aware policies (replan) step their
            // carbon governor / pattern learner on every event edge.
            policy.tick(t)?;
            match kind {
                EvKind::Timeout { node: j } => {
                    let j = j as usize;
                    if nodes[j].next_timeout != Some(t) {
                        continue; // superseded by a size flush, kill, or later deadline
                    }
                    nodes[j].next_timeout = None;
                    let node = &mut nodes[j];
                    if node.pending > 0
                        && window.aged(node.fifo[node.fifo.len() - node.pending].arrive_ns, t)
                    {
                        let size = node.pending;
                        node.pending = 0;
                        node.ready.push_back(size);
                        try_start(j, t, &mut nodes, &mut heap, &mut seq);
                    }
                    schedule_timeout(j, &mut nodes, &mut heap, &mut seq);
                }
                EvKind::Complete { node: j, gen } => {
                    let j = j as usize;
                    if nodes[j].rep.gen != gen {
                        continue; // batch aborted by a kill; its work was requeued
                    }
                    let k = nodes[j].rep.model;
                    let node = &mut nodes[j];
                    let size = node.running;
                    debug_assert!(size > 0, "Complete on an idle node");
                    let start = node.running_start;
                    node.running = 0;
                    node.rep.breaker_note_success();
                    node.stats.batches += 1;
                    node.stats.busy_s += (t - start) as f64 / 1e9;
                    for _ in 0..size {
                        let f = node.fifo.pop_front().expect("running batch members in fifo");
                        let qi = f.query as usize;
                        // Hedge losers finish (they held the engine) but
                        // are never recorded and their energy is unpaid.
                        if let Some(s) = surv.as_mut() {
                            if !s.completed(qi) {
                                continue;
                            }
                        }
                        let e = energy_of(k, qi);
                        let p = phase_of(k, qi);
                        // As-if-streamed first token: own prefill + first
                        // decode step from batch start, never after the
                        // batch completes.
                        let first_token = start
                            .saturating_add(p.prefill_ns)
                            .saturating_add(p.step_ns)
                            .min(t);
                        node.stats.queries += 1;
                        node.stats.energy_j += e;
                        node.stats.prefill_j += p.prefill_j;
                        recorder.record(
                            queries[qi].id as u64,
                            k,
                            f.arrive_ns,
                            start,
                            first_token,
                            t,
                            queries[qi].t_out,
                            e,
                            p.prefill_j,
                        );
                        if let Some(m) = meter.as_mut() {
                            m.record(t, e);
                        }
                        policy.on_complete((start - f.arrive_ns) as f64 / 1e9);
                    }
                    try_start(j, t, &mut nodes, &mut heap, &mut seq);
                }
                EvKind::Retry { query } => {
                    let s = surv.as_mut().expect("Retry event without resilience");
                    let qi = query as usize;
                    if s.recorded[qi] {
                        // A hedge twin answered during the backoff.
                        s.copies[qi] = s.copies[qi].saturating_sub(1);
                        continue;
                    }
                    let k = policy.route_at(t, &queries[qi])?;
                    debug_assert!(k < self.sets.len());
                    enqueue(
                        k,
                        InFlight {
                            query,
                            arrive_ns: to_ns(arrivals_s[qi]),
                        },
                        t,
                        &mut nodes,
                        &model_nodes,
                        &mut parked,
                        &mut heap,
                        &mut seq,
                        &mut surv,
                    );
                }
                EvKind::Hedge { query, model } => {
                    let s = surv.as_mut().expect("Hedge event without resilience");
                    let qi = query as usize;
                    if s.recorded[qi] || s.failed[qi] {
                        continue;
                    }
                    // Least-loaded up, breaker-closed replica other than
                    // the one holding the primary copy; no eligible twin
                    // target means the hedge simply does not fire.
                    let k = model as usize;
                    let excl = s.holder[qi];
                    let mut best: Option<usize> = None;
                    for &j in &model_nodes[k] {
                        if j as u32 == excl || !nodes[j].rep.up || !nodes[j].rep.breaker_ok(t) {
                            continue;
                        }
                        if best.map_or(true, |b| nodes[j].fifo.len() < nodes[b].fifo.len()) {
                            best = Some(j);
                        }
                    }
                    if let Some(j) = best {
                        s.copies[qi] += 1;
                        nodes[j].stats.hedges += 1;
                        place(
                            j,
                            InFlight {
                                query,
                                arrive_ns: to_ns(arrivals_s[qi]),
                            },
                            t,
                            &mut nodes,
                            &mut heap,
                            &mut seq,
                        );
                    }
                }
            }
        }

        for node in &nodes {
            debug_assert!(
                node.fifo.is_empty()
                    && node.ready.is_empty()
                    && node.running == 0
                    && node.pending == 0
            );
        }
        Ok((
            nodes
                .into_iter()
                .map(|n| {
                    let mut stats = n.stats;
                    n.rep.finalize(t_last, &mut stats);
                    stats
                })
                .collect(),
            surv.map_or(0, |s| s.n_failed),
        ))
    }

    /// Iteration-level continuous-batching event loop. Per node: queued
    /// arrivals are admitted into free working-set slots at iteration
    /// boundaries, each iteration runs either the oldest unprefilled
    /// member's prefill chunk or one decode step for the whole working
    /// set, and sequences retire the instant their last token is decoded.
    /// `NodeStats::batches` counts *iterations* under this engine, and
    /// every per-query energy recorded is the same fitted whole-query
    /// prediction the lockstep engine uses — which is what keeps totals
    /// identical across engines.
    #[allow(clippy::too_many_arguments)]
    fn run_continuous(
        &self,
        queries: &[Query],
        arrivals_s: &[f64],
        policy: &mut SimPolicy,
        order: &[u64],
        admitted: usize,
        fails: &[FailEv],
        window: BatchWindow,
        energy_of: &dyn Fn(usize, usize) -> f64,
        phase_of: &dyn Fn(usize, usize) -> PhaseEntry,
        recorder: &mut MetricsRecorder,
        meter: &mut Option<CarbonMeter>,
    ) -> anyhow::Result<(Vec<NodeStats>, u64)> {
        // Flat replica fleet, model-major (see `run_lockstep`).
        let mut surv = self.resilience.map(|rc| Survival::new(rc, queries.len()));
        let mut nodes: Vec<CNode> = Vec::new();
        let mut model_nodes: Vec<Vec<usize>> = Vec::with_capacity(self.sets.len());
        for (k, s) in self.sets.iter().enumerate() {
            let mut idxs = Vec::with_capacity(self.replicas[k]);
            for r in 0..self.replicas[k] {
                idxs.push(nodes.len());
                nodes.push(CNode {
                    queue: VecDeque::new(),
                    active: Vec::new(),
                    iter: None,
                    iter_start: 0,
                    rep: RepState::new(k, r as u32),
                    stats: NodeStats {
                        model_id: s.model_id.clone(),
                        ..NodeStats::default()
                    },
                });
            }
            model_nodes.push(idxs);
        }
        let mut parked: Vec<VecDeque<InFlight>> = vec![VecDeque::new(); self.sets.len()];

        let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
        let mut seq = 0u64;

        // Begin the next iteration on an idle node: admit queued arrivals
        // into free slots (FIFO, greedy — no age trigger), then run one
        // prefill chunk (oldest unprefilled member) or one decode step
        // for the whole working set (slowest member's step).
        let start_iteration =
            |j: usize, t: u64, nodes: &mut Vec<CNode>, heap: &mut BinaryHeap<Ev>, seq: &mut u64| {
                let node = &mut nodes[j];
                if node.iter.is_some() {
                    return;
                }
                let k = node.rep.model;
                while window.slots_free(node.active.len()) > 0 {
                    let Some(f) = node.queue.pop_front() else {
                        break;
                    };
                    node.active.push(ActiveSeq {
                        query: f.query,
                        arrive_ns: f.arrive_ns,
                        start_ns: t,
                        first_token_ns: u64::MAX,
                        prefilled: false,
                        steps_left: queries[f.query as usize].t_out,
                    });
                }
                if node.active.is_empty() {
                    return;
                }
                let dur = match node.active.iter().position(|a| !a.prefilled) {
                    Some(mi) => {
                        node.iter = Some(IterKind::Prefill { member: mi });
                        phase_of(k, node.active[mi].query as usize).prefill_ns
                    }
                    None => {
                        node.iter = Some(IterKind::Decode);
                        node.active
                            .iter()
                            .map(|a| phase_of(k, a.query as usize).step_ns)
                            .max()
                            .expect("decode iteration over a non-empty working set")
                    }
                };
                node.iter_start = t;
                heap.push(Ev {
                    t: t.saturating_add(dur),
                    seq: *seq,
                    kind: EvKind::Complete {
                        node: j as u32,
                        gen: node.rep.gen,
                    },
                });
                *seq += 1;
            };

        // Least-loaded up replica (queued + resident work, lowest index
        // on ties); `None` while the whole fleet is down. As in lockstep,
        // an open breaker only diverts — it never blackholes the model.
        let pick = |k: usize,
                    t: u64,
                    nodes: &Vec<CNode>,
                    model_nodes: &[Vec<usize>]|
         -> Option<usize> {
            let mut best: Option<usize> = None;
            let mut best_any: Option<usize> = None;
            let load = |n: &CNode| n.queue.len() + n.active.len();
            for &j in &model_nodes[k] {
                if !nodes[j].rep.up {
                    continue;
                }
                if best_any.map_or(true, |b| load(&nodes[j]) < load(&nodes[b])) {
                    best_any = Some(j);
                }
                if nodes[j].rep.breaker_ok(t)
                    && best.map_or(true, |b| load(&nodes[j]) < load(&nodes[b]))
                {
                    best = Some(j);
                }
            }
            best.or(best_any)
        };

        // Put one query on node `j`: idle node — the query opens an
        // iteration immediately; busy node — it joins at the next
        // boundary.
        let place = |j: usize,
                     f: InFlight,
                     t: u64,
                     nodes: &mut Vec<CNode>,
                     heap: &mut BinaryHeap<Ev>,
                     seq: &mut u64| {
            nodes[j].queue.push_back(f);
            start_iteration(j, t, nodes, heap, seq);
        };

        // Hand one query (arrival, requeue, retry, or parked flush) to
        // model `k`.
        let enqueue = |k: usize,
                       f: InFlight,
                       t: u64,
                       nodes: &mut Vec<CNode>,
                       model_nodes: &[Vec<usize>],
                       parked: &mut Vec<VecDeque<InFlight>>,
                       heap: &mut BinaryHeap<Ev>,
                       seq: &mut u64,
                       surv: &mut Option<Survival>| {
            match pick(k, t, nodes, model_nodes) {
                Some(j) => {
                    if let Some(s) = surv.as_mut() {
                        s.placed(f.query as usize, j);
                    }
                    place(j, f, t, nodes, heap, seq);
                }
                None => {
                    if let Some(s) = surv.as_mut() {
                        s.parked(f.query as usize);
                    }
                    parked[k].push_back(f);
                }
            }
        };

        let mut next_arrival = 0usize;
        let mut next_fail = 0usize;
        let mut t_last = 0u64;
        loop {
            // Event-time ties resolve failures < arrivals < iteration
            // completions — the same total order the lockstep engine
            // guarantees.
            let arrival_t = (next_arrival < admitted)
                .then(|| to_ns(arrivals_s[order[next_arrival] as usize]));
            let fail_t = (next_fail < fails.len()).then(|| fails[next_fail].t);
            let take_fail = match fail_t {
                Some(tf) => {
                    arrival_t.map_or(true, |ta| tf <= ta)
                        && heap.peek().map_or(true, |ev| tf <= ev.t)
                }
                None => false,
            };
            if take_fail {
                let fe = fails[next_fail];
                next_fail += 1;
                let (t, k, r) = (fe.t, fe.model, fe.replica);
                t_last = t_last.max(t);
                match fe.action {
                    FailAction::Kill | FailAction::Drain => {
                        let verb = if fe.action == FailAction::Kill {
                            "kill"
                        } else {
                            "drain"
                        };
                        let Some(&j) = model_nodes[k].get(r) else {
                            anyhow::bail!(
                                "failure script: {verb} targets model {k} replica {r} but only \
                                 {} exist",
                                model_nodes[k].len()
                            );
                        };
                        if !nodes[j].rep.up {
                            anyhow::bail!(
                                "failure script: {verb} of model {k} replica {r} at t={:.3}s \
                                 but it is already down",
                                t as f64 / 1e9
                            );
                        }
                        nodes[j].rep.up = false;
                        nodes[j].rep.down_since = Some(t);
                        if fe.action == FailAction::Kill {
                            // Abort the running iteration (stale by
                            // generation) and requeue the working set in
                            // admission order, then the queue — arrival
                            // times intact, no energy spent.
                            nodes[j].rep.gen += 1;
                            nodes[j].iter = None;
                            let mut orphans: Vec<InFlight> = nodes[j]
                                .active
                                .drain(..)
                                .map(|a| InFlight {
                                    query: a.query,
                                    arrive_ns: a.arrive_ns,
                                })
                                .collect();
                            orphans.extend(nodes[j].queue.drain(..));
                            nodes[j].stats.requeued += orphans.len() as u64;
                            if let Some(rc) = self.resilience.as_ref() {
                                if nodes[j].rep.breaker_note_kill(t, rc) {
                                    nodes[j].stats.breaker_trips += 1;
                                }
                            }
                            if surv.is_some() {
                                // Resilience: backoff-then-retry, or die
                                // once the budget is spent (see lockstep).
                                let s = surv.as_mut().expect("checked above");
                                for f in orphans {
                                    match s.orphaned(f.query as usize) {
                                        OrphanFate::Retry { delay_ns } => {
                                            nodes[j].stats.retries += 1;
                                            heap.push(Ev {
                                                t: t.saturating_add(delay_ns),
                                                seq,
                                                kind: EvKind::Retry { query: f.query },
                                            });
                                            seq += 1;
                                        }
                                        OrphanFate::Dropped => {}
                                    }
                                }
                            } else {
                                for f in orphans {
                                    enqueue(
                                        k, f, t, &mut nodes, &model_nodes, &mut parked,
                                        &mut heap, &mut seq, &mut surv,
                                    );
                                }
                            }
                        }
                        // Drain needs no flush: admission is greedy, so
                        // the node simply stops receiving and its queued
                        // work retires through the usual iterations.
                    }
                    FailAction::Create => {
                        let fleet = model_nodes[k].len();
                        if r < fleet {
                            let j = model_nodes[k][r];
                            if nodes[j].rep.up {
                                anyhow::bail!(
                                    "failure script: join targets model {k} replica {r} at \
                                     t={:.3}s but it is up",
                                    t as f64 / 1e9
                                );
                            }
                            if nodes[j].rep.joining {
                                anyhow::bail!(
                                    "failure script: overlapping joins for model {k} replica {r}"
                                );
                            }
                            nodes[j].rep.joining = true;
                        } else if r == fleet {
                            let j = nodes.len();
                            nodes.push(CNode {
                                queue: VecDeque::new(),
                                active: Vec::new(),
                                iter: None,
                                iter_start: 0,
                                rep: RepState::joining(k, r as u32, t),
                                stats: NodeStats {
                                    model_id: self.sets[k].model_id.clone(),
                                    ..NodeStats::default()
                                },
                            });
                            model_nodes[k].push(j);
                        } else {
                            anyhow::bail!(
                                "failure script: join targets model {k} replica {r} but only \
                                 {fleet} exist (replica indices are contiguous)"
                            );
                        }
                    }
                    FailAction::Activate => {
                        let j = model_nodes[k][r];
                        debug_assert!(nodes[j].rep.joining, "Activate without its Create");
                        nodes[j].rep.joining = false;
                        nodes[j].rep.up = true;
                        nodes[j].rep.settle_downtime(t);
                        let flushed: Vec<InFlight> = parked[k].drain(..).collect();
                        for f in flushed {
                            enqueue(
                                k, f, t, &mut nodes, &model_nodes, &mut parked, &mut heap,
                                &mut seq, &mut surv,
                            );
                        }
                    }
                }
                let up = model_nodes[k].iter().filter(|&&j| nodes[j].rep.up).count();
                policy.on_capacity(k, up)?;
                continue;
            }
            let take_arrival = match (arrival_t, heap.peek()) {
                (Some(ta), Some(ev)) => ta <= ev.t,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            if take_arrival {
                let qi = order[next_arrival] as usize;
                next_arrival += 1;
                let t = arrival_t.unwrap();
                t_last = t_last.max(t);
                let k = policy.route_at(t, &queries[qi])?;
                debug_assert!(k < self.sets.len());
                if let Some(s) = surv.as_mut() {
                    s.copies[qi] = 1;
                    if let Some(h) = s.cfg.hedge_after_s {
                        heap.push(Ev {
                            t: t.saturating_add(to_ns(h)),
                            seq,
                            kind: EvKind::Hedge {
                                query: qi as u64,
                                model: k as u32,
                            },
                        });
                        seq += 1;
                    }
                }
                enqueue(
                    k,
                    InFlight {
                        query: qi as u64,
                        arrive_ns: t,
                    },
                    t,
                    &mut nodes,
                    &model_nodes,
                    &mut parked,
                    &mut heap,
                    &mut seq,
                    &mut surv,
                );
                continue;
            }
            let Ev { t, kind, .. } = heap.pop().unwrap();
            t_last = t_last.max(t);
            policy.tick(t)?;
            let (j, gen) = match kind {
                EvKind::Complete { node, gen } => (node as usize, gen),
                EvKind::Retry { query } => {
                    let s = surv.as_mut().expect("Retry event without resilience");
                    let qi = query as usize;
                    if s.recorded[qi] {
                        // A hedge twin answered during the backoff.
                        s.copies[qi] = s.copies[qi].saturating_sub(1);
                        continue;
                    }
                    let k = policy.route_at(t, &queries[qi])?;
                    debug_assert!(k < self.sets.len());
                    enqueue(
                        k,
                        InFlight {
                            query,
                            arrive_ns: to_ns(arrivals_s[qi]),
                        },
                        t,
                        &mut nodes,
                        &model_nodes,
                        &mut parked,
                        &mut heap,
                        &mut seq,
                        &mut surv,
                    );
                    continue;
                }
                EvKind::Hedge { query, model } => {
                    let s = surv.as_mut().expect("Hedge event without resilience");
                    let qi = query as usize;
                    if s.recorded[qi] || s.failed[qi] {
                        continue;
                    }
                    // Least-loaded up, breaker-closed replica other than
                    // the primary copy's holder (see lockstep).
                    let k = model as usize;
                    let excl = s.holder[qi];
                    let load = |n: &CNode| n.queue.len() + n.active.len();
                    let mut best: Option<usize> = None;
                    for &j in &model_nodes[k] {
                        if j as u32 == excl || !nodes[j].rep.up || !nodes[j].rep.breaker_ok(t) {
                            continue;
                        }
                        if best.map_or(true, |b| load(&nodes[j]) < load(&nodes[b])) {
                            best = Some(j);
                        }
                    }
                    if let Some(j) = best {
                        s.copies[qi] += 1;
                        nodes[j].stats.hedges += 1;
                        place(
                            j,
                            InFlight {
                                query,
                                arrive_ns: to_ns(arrivals_s[qi]),
                            },
                            t,
                            &mut nodes,
                            &mut heap,
                            &mut seq,
                        );
                    }
                    continue;
                }
                EvKind::Timeout { .. } => {
                    unreachable!("continuous engine schedules no timeouts")
                }
            };
            if nodes[j].rep.gen != gen {
                continue; // iteration aborted by a kill; its work was requeued
            }
            let k = nodes[j].rep.model;
            let node = &mut nodes[j];
            let iter = node.iter.take().expect("Complete on an idle node");
            node.rep.breaker_note_success();
            node.stats.batches += 1; // iterations, under this engine
            node.stats.busy_s += (t - node.iter_start) as f64 / 1e9;
            match iter {
                IterKind::Prefill { member } => {
                    node.active[member].prefilled = true;
                }
                IterKind::Decode => {
                    for a in node.active.iter_mut() {
                        a.steps_left = a.steps_left.saturating_sub(1);
                        if a.first_token_ns == u64::MAX {
                            a.first_token_ns = t;
                        }
                    }
                }
            }
            // Retire finished sequences immediately, in admission order.
            let mut i = 0;
            while i < node.active.len() {
                if node.active[i].prefilled && node.active[i].steps_left == 0 {
                    let a = node.active.remove(i);
                    let qi = a.query as usize;
                    // Hedge losers retire unrecorded and unpaid (see
                    // the lockstep completion path).
                    if let Some(s) = surv.as_mut() {
                        if !s.completed(qi) {
                            continue;
                        }
                    }
                    let e = energy_of(k, qi);
                    let pj = phase_of(k, qi).prefill_j;
                    // Zero-generation sequences never decode: their first
                    // (and only) response instant is retirement itself.
                    let first_token = if a.first_token_ns == u64::MAX {
                        t
                    } else {
                        a.first_token_ns
                    };
                    node.stats.queries += 1;
                    node.stats.energy_j += e;
                    node.stats.prefill_j += pj;
                    recorder.record(
                        queries[qi].id as u64,
                        k,
                        a.arrive_ns,
                        a.start_ns,
                        first_token,
                        t,
                        queries[qi].t_out,
                        e,
                        pj,
                    );
                    if let Some(m) = meter.as_mut() {
                        m.record(t, e);
                    }
                    policy.on_complete((a.start_ns - a.arrive_ns) as f64 / 1e9);
                } else {
                    i += 1;
                }
            }
            start_iteration(j, t, &mut nodes, &mut heap, &mut seq);
        }

        for node in &nodes {
            debug_assert!(node.queue.is_empty() && node.active.is_empty() && node.iter.is_none());
        }
        Ok((
            nodes
                .into_iter()
                .map(|n| {
                    let mut stats = n.stats;
                    n.rep.finalize(t_last, &mut stats);
                    stats
                })
                .collect(),
            surv.map_or(0, |s| s.n_failed),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Normalizer;
    use crate::sim::PolicyKind;
    use crate::testkit::synthetic_pair as sets;

    fn q(id: u32, t_in: u32, t_out: u32) -> Query {
        Query { id, t_in, t_out }
    }

    fn norm(sets: &[ModelSet]) -> Normalizer {
        let probe: Vec<Query> = (1..50).map(|i| q(i, 10 * i, 20 * i)).collect();
        Normalizer::from_workload(sets, &probe)
    }

    fn greedy(s: &[ModelSet], zeta: f64) -> SimPolicy {
        SimPolicy::new(PolicyKind::Greedy, s, norm(s), zeta, None, 7, None).unwrap()
    }

    /// Tests that inspect per-query lifecycles opt into retention.
    fn cfg_per_query(cfg: SimConfig) -> SimConfig {
        SimConfig {
            per_query: true,
            ..cfg
        }
    }

    #[test]
    fn single_query_waits_out_the_age_trigger() {
        let s = sets();
        let cfg = cfg_per_query(SimConfig {
            max_batch: 8,
            max_wait_s: 0.5,
            ..SimConfig::default()
        });
        let queries = vec![q(0, 100, 100)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[1.0], &mut greedy(&s, 1.0))
            .unwrap();
        assert_eq!(m.n_queries, 1);
        let o = m.outcomes.as_ref().unwrap()[0];
        // ζ=1 greedy routes to the energy-min model ("small").
        assert_eq!(o.model, 0);
        assert_eq!(o.t_arrive, 1.0);
        // Alone in the batcher: starts exactly at arrival + max_wait.
        assert!((o.t_start - 1.5).abs() < 1e-9, "t_start={}", o.t_start);
        let service = s[0].runtime.predict(100.0, 100.0);
        assert!(
            (o.t_complete - (1.5 + service)).abs() < 1e-6,
            "t_complete={}",
            o.t_complete
        );
        assert!((m.total_energy_j - s[0].energy.predict(100.0, 100.0)).abs() < 1e-9);
        assert_eq!(m.nodes[0].batches, 1);
        assert_eq!(m.nodes[1].batches, 0);
        // First token lands after start, never after completion.
        assert!(o.t_start <= o.t_first_token && o.t_first_token <= o.t_complete);
    }

    #[test]
    fn size_trigger_starts_immediately() {
        let s = sets();
        let cfg = cfg_per_query(SimConfig {
            max_batch: 2,
            max_wait_s: 10.0,
            ..SimConfig::default()
        });
        let queries = vec![q(0, 50, 50), q(1, 100, 100)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[0.0, 0.0], &mut greedy(&s, 1.0))
            .unwrap();
        // Both land on "small"; batch fills instantly → zero queue wait.
        assert_eq!(m.mean_queue_s, 0.0);
        assert_eq!(m.p95_queue_s, 0.0);
        assert_eq!(m.nodes[0].batches, 1);
        // Lockstep batch: both complete at the slower member's runtime.
        let slow = s[0].runtime.predict(100.0, 100.0);
        for o in m.outcomes.as_ref().unwrap() {
            assert!((o.t_complete - slow).abs() < 1e-6);
        }
    }

    #[test]
    fn busy_engine_queues_the_next_batch() {
        let s = sets();
        let cfg = cfg_per_query(SimConfig {
            max_batch: 1, // every query is its own batch
            max_wait_s: 10.0,
            ..SimConfig::default()
        });
        let queries = vec![q(0, 200, 400), q(1, 200, 400)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[0.0, 0.0], &mut greedy(&s, 1.0))
            .unwrap();
        let service = s[0].runtime.predict(200.0, 400.0);
        let mut by_id = m.outcomes.clone().unwrap();
        by_id.sort_by_key(|o| o.id);
        // First batch runs [0, service); second starts when the engine
        // frees, so its queue wait is one full service time.
        assert!((by_id[0].t_start - 0.0).abs() < 1e-9);
        assert!((by_id[1].t_start - service).abs() < 1e-6);
        assert!((m.makespan_s - 2.0 * service).abs() < 1e-6);
        assert!((m.nodes[0].busy_s - 2.0 * service).abs() < 1e-6);
    }

    #[test]
    fn duration_cap_drops_late_arrivals() {
        let s = sets();
        let cfg = cfg_per_query(SimConfig {
            duration_s: Some(1.0),
            ..SimConfig::default()
        });
        let queries = vec![q(0, 10, 10), q(1, 10, 10), q(2, 10, 10)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[0.5, 2.0, 1.0], &mut greedy(&s, 0.5))
            .unwrap();
        assert_eq!(m.n_queries, 2);
        assert_eq!(m.n_dropped, 1);
        let served: Vec<u64> = {
            let mut ids: Vec<u64> =
                m.outcomes.as_ref().unwrap().iter().map(|o| o.id).collect();
            ids.sort();
            ids
        };
        assert_eq!(served, vec![0, 2]);
    }

    #[test]
    fn conservation_across_random_streams() {
        use crate::testkit::{forall, Config};
        let s = sets();
        forall(Config::default().cases(30), |rng| {
            let n = rng.int_range(1, 120) as usize;
            let queries: Vec<Query> = (0..n)
                .map(|i| {
                    q(
                        i as u32,
                        rng.int_range(1, 500) as u32,
                        rng.int_range(1, 500) as u32,
                    )
                })
                .collect();
            let arrivals: Vec<f64> = (0..n).map(|_| rng.range(0.0, 3.0)).collect();
            let engine = if rng.chance(0.5) {
                EngineKind::Continuous
            } else {
                EngineKind::Lockstep
            };
            let cfg = cfg_per_query(SimConfig {
                max_batch: rng.int_range(1, 6) as usize,
                max_wait_s: rng.range(0.0, 0.2),
                engine,
                ..SimConfig::default()
            });
            let mut policy = greedy(&s, rng.range(0.0, 1.0));
            let m = Simulator::new(&s, cfg)
                .run(&queries, &arrivals, &mut policy)
                .unwrap();
            assert_eq!(m.n_queries as usize, n);
            let outcomes = m.outcomes.as_ref().unwrap();
            // Each query served exactly once.
            let mut ids: Vec<u64> = outcomes.iter().map(|o| o.id).collect();
            ids.sort();
            assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
            // Causality: arrive ≤ start ≤ first token ≤ complete.
            for o in outcomes {
                assert!(o.t_arrive <= o.t_start + 1e-12);
                assert!(o.t_start <= o.t_first_token + 1e-12);
                assert!(o.t_first_token <= o.t_complete + 1e-12);
            }
            // Energy is conserved: node totals equal the streaming total,
            // and per-phase energies partition each node's total.
            let node_total: f64 = m.nodes.iter().map(|nd| nd.energy_j).sum();
            assert!((node_total - m.total_energy_j).abs() < 1e-6);
            for nd in &m.nodes {
                assert!(nd.prefill_j >= 0.0 && nd.prefill_j <= nd.energy_j + 1e-9);
            }
            assert!(
                (m.prefill_energy_j + m.decode_energy_j - m.total_energy_j).abs() < 1e-6
            );
            // And the streaming histograms saw every completion.
            assert_eq!(m.latency_hist.n(), n as u64);
            assert_eq!(m.queue_hist.n(), n as u64);
            assert_eq!(m.ttft_hist.n(), n as u64);
            assert_eq!(m.tpot_hist.n(), n as u64);
        });
    }

    /// Memoized prediction tables change the cost of the hot path, never
    /// its results: byte-identical artifacts with the tables on and off —
    /// under both engines (the memo also carries the phase split).
    #[test]
    fn memoization_is_invisible_in_the_artifact() {
        use crate::testkit::{forall, Config};
        let s = sets();
        forall(Config::default().cases(10), |rng| {
            let n = rng.int_range(5, 80) as usize;
            // Few distinct shapes → the memo table actually gets reuse.
            let queries: Vec<Query> = (0..n)
                .map(|i| {
                    let sh = 1 + 37 * rng.int_range(1, 5) as u32;
                    q(i as u32, sh, 2 * sh)
                })
                .collect();
            let arrivals: Vec<f64> = (0..n).map(|_| rng.range(0.0, 2.0)).collect();
            let zeta = rng.range(0.0, 1.0);
            let engine = if rng.chance(0.5) {
                EngineKind::Continuous
            } else {
                EngineKind::Lockstep
            };
            let run = |memoize: bool| {
                let cfg = SimConfig {
                    max_batch: 3,
                    max_wait_s: 0.05,
                    memoize,
                    engine,
                    ..SimConfig::default()
                };
                Simulator::new(&s, cfg)
                    .labeled("trace", 9, zeta)
                    .run(&queries, &arrivals, &mut greedy(&s, zeta))
                    .unwrap()
                    .to_json()
                    .to_string_pretty()
            };
            assert_eq!(run(true), run(false));
        });
    }

    #[test]
    fn continuous_engine_retires_members_independently() {
        let s = sets();
        let cfg = cfg_per_query(SimConfig {
            max_batch: 2,
            engine: EngineKind::Continuous,
            ..SimConfig::default()
        });
        // Same prompt, very different generation lengths, arriving
        // together: under lockstep both would complete at the slow
        // member's finish; continuous retires the short one early.
        let queries = vec![q(0, 100, 10), q(1, 100, 400)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[0.0, 0.0], &mut greedy(&s, 1.0))
            .unwrap();
        let mut by_id = m.outcomes.clone().unwrap();
        by_id.sort_by_key(|o| o.id);
        assert!(
            by_id[0].t_complete < by_id[1].t_complete,
            "short sequence must retire first: {} vs {}",
            by_id[0].t_complete,
            by_id[1].t_complete
        );
        // Energy is still the fitted whole-query prediction per member.
        let e0 = s[0].energy.predict(100.0, 10.0);
        let e1 = s[0].energy.predict(100.0, 400.0);
        assert!((m.total_energy_j - (e0 + e1)).abs() < 1e-9);
        // Iterations, not batches: one prefill each + interleaved decode.
        assert!(m.nodes[0].batches > 2, "batches={}", m.nodes[0].batches);
    }

    #[test]
    fn continuous_engine_skips_the_batch_formation_wait() {
        let s = sets();
        let mk = |engine| {
            cfg_per_query(SimConfig {
                max_batch: 8,
                max_wait_s: 0.5,
                engine,
                ..SimConfig::default()
            })
        };
        let queries = vec![q(0, 100, 100)];
        let lock = Simulator::new(&s, mk(EngineKind::Lockstep))
            .run(&queries, &[1.0], &mut greedy(&s, 1.0))
            .unwrap();
        let cont = Simulator::new(&s, mk(EngineKind::Continuous))
            .run(&queries, &[1.0], &mut greedy(&s, 1.0))
            .unwrap();
        // Lockstep holds the lone query for the age trigger; continuous
        // admits it at arrival, so its TTFT is smaller by ≈ max_wait.
        let lo = lock.outcomes.as_ref().unwrap()[0];
        let co = cont.outcomes.as_ref().unwrap()[0];
        assert!((lo.t_start - 1.5).abs() < 1e-9);
        assert!((co.t_start - 1.0).abs() < 1e-9);
        assert!(cont.mean_ttft_s < lock.mean_ttft_s);
        // Same fitted energy either way.
        assert!((cont.total_energy_j - lock.total_energy_j).abs() < 1e-12);
    }

    #[test]
    fn phase_split_reproduces_the_fitted_service_time() {
        // The calibrated split must re-sum to the fitted whole-query
        // prediction: prefill + t_out · step ≈ service (to per-phase
        // rounding), prefill_j ∈ [0, energy].
        let s = sets();
        let splitter = PhaseSplitter::new(&s);
        for (k, set) in s.iter().enumerate() {
            for (t_in, t_out) in [(1u32, 1u32), (100, 10), (10, 1000), (512, 0)] {
                let e = splitter.entry(set, k, t_in, t_out);
                let service_ns =
                    to_ns(set.runtime.predict(t_in as f64, t_out as f64).max(0.0));
                let resum = e.prefill_ns + u64::from(t_out.max(1)) * e.step_ns;
                let tol = u64::from(t_out) + 2; // ±0.5 ns per rounded phase
                assert!(
                    resum.abs_diff(service_ns) <= tol,
                    "model {k} shape ({t_in},{t_out}): {resum} vs {service_ns}"
                );
                let energy = set.energy.predict(t_in as f64, t_out as f64);
                assert!(e.prefill_j >= 0.0 && e.prefill_j <= energy + 1e-9);
                // Zero-generation queries are all prefill.
                if t_out == 0 {
                    assert_eq!(e.step_ns * u64::from(t_out.max(1)), e.step_ns);
                    assert!((e.prefill_j - energy).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn horizon_bound_applies_only_inside_the_duration_window() {
        let s = sets();
        let queries = vec![q(0, 10, 10), q(1, 10, 10)];
        // An arrival beyond the 1e9-s virtual clock cap fails an
        // unbounded run…
        let err = Simulator::new(&s, SimConfig::default())
            .run(&queries, &[0.5, 2e9], &mut greedy(&s, 0.5))
            .unwrap_err();
        assert!(err.to_string().contains("1e9"), "{err}");
        // …but is fine when the duration cap drops it anyway.
        let cfg = SimConfig {
            duration_s: Some(1.0),
            ..SimConfig::default()
        };
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[0.5, 2e9], &mut greedy(&s, 0.5))
            .unwrap();
        assert_eq!(m.n_queries, 1);
        assert_eq!(m.n_dropped, 1);
    }

    #[test]
    fn mismatched_arrival_lengths_error() {
        let s = sets();
        let err = Simulator::new(&s, SimConfig::default())
            .run(&[q(0, 1, 1)], &[0.0, 1.0], &mut greedy(&s, 0.5))
            .unwrap_err();
        assert!(err.to_string().contains("arrival"), "{err}");
    }

    #[test]
    fn carbon_meter_totals_match_energy_times_intensity() {
        use crate::control::CarbonConfig;
        use crate::scheduler::GridSignal;
        let s = sets();
        // Flat signal: realized carbon must equal total energy converted
        // at the single intensity, however completions spread over time.
        let carbon = CarbonConfig {
            signal: GridSignal {
                hourly: vec![300.0; 24],
            },
            zeta_min: 0.5,
            zeta_max: 0.5,
            day_s: 24.0,
        };
        let queries: Vec<Query> = (0..20).map(|i| q(i, 50 + 10 * (i % 3), 80)).collect();
        let arrivals: Vec<f64> = (0..20).map(|i| 0.1 * i as f64).collect();
        let m = Simulator::new(&s, SimConfig::default())
            .with_carbon(carbon)
            .run(&queries, &arrivals, &mut greedy(&s, 0.5))
            .unwrap();
        let r = m.carbon.as_ref().unwrap();
        assert!((r.total_g - m.total_energy_j / 3.6e6 * 300.0).abs() < 1e-9);
        let windowed: f64 = r.windows.iter().map(|w| w.energy_j).sum();
        assert!((windowed - m.total_energy_j).abs() < 1e-9);
        // Metering alone adds no control plane: no ζ trajectory.
        assert!(m.zeta_trajectory.is_none());
        assert!(m.replan_stats.is_none());
    }

    #[test]
    fn kill_requeues_in_flight_work_to_the_surviving_replica() {
        use crate::sim::{FailureEvent, FailureKind, FailureScript};
        let s = sets();
        let service = s[0].runtime.predict(200.0, 400.0);
        let script = FailureScript::new(vec![FailureEvent {
            t_s: 0.5 * service, // mid-batch
            model: 0,
            replica: 0,
            kind: FailureKind::Kill,
        }])
        .unwrap();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let cfg = cfg_per_query(SimConfig {
                max_batch: 1,
                max_wait_s: 10.0,
                engine,
                ..SimConfig::default()
            });
            // ζ=1 greedy sends both to model 0; least-loaded dispatch
            // splits them across its two replicas.
            let queries = vec![q(0, 200, 400), q(1, 200, 400)];
            let m = Simulator::new(&s, cfg)
                .with_replicas(&[2, 1])
                .unwrap()
                .with_failures(&script)
                .run(&queries, &[0.0, 0.0], &mut greedy(&s, 1.0))
                .unwrap();
            // Nothing lost, nothing duplicated: the killed replica's
            // in-flight query finishes on the survivor.
            assert_eq!(m.n_queries, 2, "{engine:?}");
            assert_eq!(m.n_requeued, 1, "{engine:?}");
            assert_eq!(m.scenario, "chaos:1");
            let mut ids: Vec<u64> =
                m.outcomes.as_ref().unwrap().iter().map(|o| o.id).collect();
            ids.sort();
            assert_eq!(ids, vec![0, 1]);
            // Node rows are model-major: [m0r0, m0r1, m1r0].
            assert_eq!(m.nodes.len(), 3);
            assert_eq!(
                m.nodes.iter().map(|nd| nd.replica).collect::<Vec<_>>(),
                vec![0, 1, 0]
            );
            let killed = &m.nodes[0];
            let survivor = &m.nodes[1];
            assert_eq!(killed.requeued, 1, "{engine:?}");
            assert_eq!(killed.queries, 0, "aborted work must not complete");
            // Aborted work consumes no energy: the run's total is exactly
            // two fitted whole-query predictions, all on the survivor.
            assert!(killed.energy_j.abs() < 1e-12, "{engine:?}");
            let e = s[0].energy.predict(200.0, 400.0);
            assert!((m.total_energy_j - 2.0 * e).abs() < 1e-9, "{engine:?}");
            assert_eq!(survivor.queries, 2);
            // Downtime runs from the kill to the end of the run.
            assert!(
                (killed.downtime_s - (m.makespan_s - 0.5 * service)).abs() < 1e-6,
                "{engine:?}: downtime={} makespan={}",
                killed.downtime_s,
                m.makespan_s
            );
            assert_eq!(survivor.downtime_s, 0.0);
            // The requeued query's wait spans the abort: it completes well
            // after a clean two-query run would.
            assert!(m.makespan_s > 1.5 * service, "{engine:?}");
        }
    }

    #[test]
    fn uniform_replicas_match_the_single_node_artifact_byte_for_byte() {
        let s = sets();
        let queries: Vec<Query> = (0..40).map(|i| q(i, 20 + 15 * (i % 4), 60)).collect();
        let arrivals: Vec<f64> = (0..40).map(|i| 0.03 * i as f64).collect();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let cfg = SimConfig {
                max_batch: 3,
                max_wait_s: 0.05,
                engine,
                ..SimConfig::default()
            };
            let run = |replicated: bool| {
                let sim = Simulator::new(&s, cfg).labeled("trace", 11, 0.6);
                let sim = if replicated {
                    sim.with_replicas(&[1, 1]).unwrap()
                } else {
                    sim
                };
                sim.run(&queries, &arrivals, &mut greedy(&s, 0.6))
                    .unwrap()
                    .to_json()
                    .to_string_pretty()
            };
            assert_eq!(run(true), run(false), "{engine:?}");
        }
    }

    #[test]
    fn join_after_total_loss_flushes_parked_arrivals() {
        use crate::sim::{FailureEvent, FailureKind, FailureScript};
        let s = sets();
        // Kill model 0's lone replica while idle, then autoscale a fresh
        // one in: create at 0.6, warm until 0.8. The query arriving at 0.7
        // has no live replica and parks until the activate.
        let script = FailureScript::new(vec![
            FailureEvent {
                t_s: 0.5,
                model: 0,
                replica: 0,
                kind: FailureKind::Kill,
            },
            FailureEvent {
                t_s: 0.6,
                model: 0,
                replica: 1,
                kind: FailureKind::Join { warmup_s: 0.2 },
            },
        ])
        .unwrap();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let cfg = cfg_per_query(SimConfig {
                max_batch: 1,
                max_wait_s: 10.0,
                engine,
                ..SimConfig::default()
            });
            let m = Simulator::new(&s, cfg)
                .with_failures(&script)
                .run(&[q(0, 10, 10)], &[0.7], &mut greedy(&s, 1.0))
                .unwrap();
            assert_eq!(m.n_queries, 1, "{engine:?}");
            assert_eq!(m.n_requeued, 0);
            assert_eq!(m.scenario, "chaos:2");
            let o = m.outcomes.as_ref().unwrap()[0];
            // Parked through the warm-up: service starts at the activate.
            assert!((o.t_start - 0.8).abs() < 1e-9, "{engine:?}: {}", o.t_start);
            // The joined replica appended as model 0 replica 1.
            assert_eq!(m.nodes.len(), 3);
            let joined = &m.nodes[1];
            assert_eq!((joined.replica, joined.queries), (1, 1), "{engine:?}");
            // Warm-up counts as downtime; the dead original is down from
            // the kill to the end of the run.
            assert!((joined.downtime_s - 0.2).abs() < 1e-9, "{engine:?}");
            assert!(
                (m.nodes[0].downtime_s - (m.makespan_s - 0.5)).abs() < 1e-6,
                "{engine:?}"
            );
        }
    }

    #[test]
    fn failure_script_misuse_is_an_instructive_error() {
        use crate::sim::{FailureEvent, FailureKind, FailureScript};
        let s = sets();
        let run = |events: Vec<FailureEvent>| {
            let script = FailureScript::new(events).unwrap();
            Simulator::new(&s, SimConfig::default())
                .with_failures(&script)
                .run(&[q(0, 10, 10)], &[0.0], &mut greedy(&s, 1.0))
                .map(|_| ())
        };
        let ev = |t_s, model, replica, kind| FailureEvent {
            t_s,
            model,
            replica,
            kind,
        };
        // Replica counts of zero are rejected up front.
        let err = Simulator::new(&s, SimConfig::default())
            .with_replicas(&[0, 1])
            .unwrap_err();
        assert!(err.to_string().contains("at least one replica"), "{err}");
        // Unknown model.
        let err = run(vec![ev(1.0, 7, 0, FailureKind::Kill)]).unwrap_err();
        assert!(err.to_string().contains("only 2 are hosted"), "{err}");
        // Unknown replica.
        let err = run(vec![ev(1.0, 0, 3, FailureKind::Kill)]).unwrap_err();
        assert!(err.to_string().contains("only 1 exist"), "{err}");
        // Killing a replica that is already down.
        let err = run(vec![
            ev(0.1, 0, 0, FailureKind::Kill),
            ev(0.2, 0, 0, FailureKind::Kill),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("already down"), "{err}");
        // Joining a replica that is still up.
        let err =
            run(vec![ev(0.1, 0, 0, FailureKind::Join { warmup_s: 0.0 })]).unwrap_err();
        assert!(err.to_string().contains("it is up"), "{err}");
        // Non-contiguous fresh replica index.
        let err = run(vec![
            ev(0.1, 0, 0, FailureKind::Kill),
            ev(0.2, 0, 5, FailureKind::Join { warmup_s: 0.0 }),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("contiguous"), "{err}");
    }

    #[test]
    fn replan_policy_runs_under_the_simulator_clock() {
        use crate::control::{CarbonConfig, ControlConfig};
        let s = sets();
        let cfg = ControlConfig {
            replan_every: 8,
            slo_trigger_s: Some(0.2),
            carbon: Some(CarbonConfig {
                day_s: 24.0, // one carbon window per simulated second
                ..CarbonConfig::typical(0.2, 0.8)
            }),
        };
        let mut p =
            SimPolicy::new(PolicyKind::Replan, &s, norm(&s), 0.5, None, 7, Some(&cfg))
                .unwrap();
        let queries: Vec<Query> = (0..100)
            .map(|i| q(i, 20 + 10 * (i % 4), 40 + 20 * (i % 3)))
            .collect();
        // Spans ~5 virtual seconds → several carbon windows.
        let arrivals: Vec<f64> = (0..100).map(|i| 0.05 * i as f64).collect();
        let m = Simulator::new(&s, SimConfig::default())
            .with_carbon(cfg.carbon.clone().unwrap())
            .labeled("fixed", 7, 0.5)
            .run(&queries, &arrivals, &mut p)
            .unwrap();
        assert_eq!(m.policy, "replan");
        assert_eq!(m.n_queries, 100);
        let rs = m.replan_stats.unwrap();
        assert!(rs.replans >= 1, "{rs:?}");
        assert_eq!(rs.planned_routed + rs.fallback_routed, 100, "{rs:?}");
        assert!(m.carbon.is_some());
        assert!(!m.zeta_trajectory.as_ref().unwrap().is_empty());
    }
}
