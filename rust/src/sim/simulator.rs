//! The discrete-event serving simulator: arrival → route → batch →
//! execute → complete, on a virtual integer-nanosecond clock.
//!
//! # Event model
//!
//! One node per hosted model, each with a
//! [`Batcher`](crate::coordinator::Batcher) (the production accumulation
//! queue, driven here with injected virtual timestamps) and a serial
//! engine. Three event kinds drive the run:
//!
//! * **Arrive** — the policy routes the query to a node; the node's
//!   batcher either flushes a full batch (size trigger) or the node
//!   schedules a timeout at the batcher's deadline (age trigger).
//! * **Timeout** — the node polls its batcher at the deadline; an aged
//!   batch moves to the ready queue.
//! * **Complete** — the engine frees, accounts the batch (service time =
//!   slowest member's predicted runtime, energy = sum of members'
//!   predicted energies), and starts the next ready batch.
//!
//! # Determinism contract
//!
//! The clock is a `u64` of virtual nanoseconds; ties pop in event-creation
//! order (a strictly increasing sequence number). Service times and
//! energies come from the fitted [`ModelSet`](crate::models::ModelSet)
//! predictions, arrivals from a seeded [`Rng`](crate::util::Rng) — no
//! wall-clock reads, no thread scheduling, no hash-order iteration feed
//! any decision. Equal `(sets, queries, arrivals, policy, seed, config)`
//! therefore produce identical [`SimMetrics`], byte-for-byte in JSON;
//! `tests/sim.rs` and the CI `sim-smoke` step both enforce this.

use super::metrics::{NodeStats, QueryOutcome, SimMetrics};
use super::policy::SimPolicy;
use crate::coordinator::{Batch, Batcher, Request};
use crate::models::ModelSet;
use crate::workload::Query;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};
use std::time::{Duration, Instant};

/// Knobs of the simulated serving tier.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// per-node batch size trigger
    pub max_batch: usize,
    /// per-node batch age trigger, seconds
    pub max_wait_s: f64,
    /// latency SLO the attainment metric is measured against, seconds
    pub slo_s: f64,
    /// drop arrivals after this virtual time (open-ended when `None`)
    pub duration_s: Option<f64>,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            max_batch: 8,
            max_wait_s: 0.05,
            slo_s: 30.0,
            duration_s: None,
        }
    }
}

/// A configured simulator: the hosted models plus run metadata recorded
/// into the metrics artifact.
pub struct Simulator<'a> {
    sets: &'a [ModelSet],
    cfg: SimConfig,
    arrival_label: String,
    seed: u64,
    zeta: f64,
}

enum EvKind {
    /// query index arrives
    Arrive(usize),
    /// node's batcher deadline fires
    Timeout(usize),
    /// node finishes the batch started at `start` over `members`
    Complete {
        node: usize,
        start: u64,
        members: Vec<usize>,
    },
}

struct Ev {
    t: u64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Ev) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    /// Reversed on `(t, seq)` so `BinaryHeap` (a max-heap) pops the
    /// earliest event, FIFO among ties.
    fn cmp(&self, other: &Ev) -> Ordering {
        other.t.cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct Node {
    batcher: Batcher,
    busy: bool,
    ready: VecDeque<Batch>,
    /// dedupes Timeout events: only the one matching this value acts
    next_timeout: Option<u64>,
    stats: NodeStats,
}

impl<'a> Simulator<'a> {
    pub fn new(sets: &'a [ModelSet], cfg: SimConfig) -> Simulator<'a> {
        assert!(!sets.is_empty(), "simulator needs at least one model");
        assert!(cfg.max_batch > 0, "max_batch must be positive");
        assert!(
            cfg.max_wait_s.is_finite() && (0.0..=1e9).contains(&cfg.max_wait_s),
            "max_wait_s must be finite and in [0, 1e9]"
        );
        Simulator {
            sets,
            cfg,
            arrival_label: "trace".to_string(),
            seed: 0,
            zeta: 0.5,
        }
    }

    /// Record run metadata (arrival process label, seed, ζ) into the
    /// produced artifact.
    pub fn labeled(mut self, arrival: &str, seed: u64, zeta: f64) -> Simulator<'a> {
        self.arrival_label = arrival.to_string();
        self.seed = seed;
        self.zeta = zeta;
        self
    }

    /// Replay `queries` arriving at `arrivals_s` (seconds, parallel to
    /// `queries`, any order) through `policy` on the simulated cluster.
    pub fn run(
        &self,
        queries: &[Query],
        arrivals_s: &[f64],
        policy: &mut SimPolicy,
    ) -> anyhow::Result<SimMetrics> {
        if queries.len() != arrivals_s.len() {
            anyhow::bail!(
                "{} queries but {} arrival times",
                queries.len(),
                arrivals_s.len()
            );
        }
        // The upper bound keeps virtual nanoseconds far inside u64/Instant
        // range (1e9 s ≈ 31 years of trace time).
        if let Some(bad) = arrivals_s
            .iter()
            .find(|t| !t.is_finite() || **t < 0.0 || **t > 1e9)
        {
            anyhow::bail!("arrival times must be finite, >= 0 and <= 1e9 s, got {bad}");
        }

        // Virtual clock: u64 nanoseconds mapped onto a fixed anchor
        // Instant for the Batcher. All comparisons reduce to exact
        // integer-nanosecond arithmetic.
        let anchor = Instant::now();
        let to_ns = |s: f64| -> u64 { (s * 1e9).round() as u64 };
        let ns_to_s = |ns: u64| -> f64 { ns as f64 / 1e9 };
        let at = |ns: u64| -> Instant { anchor + Duration::from_nanos(ns) };

        let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
        let mut seq = 0u64;

        // Arrivals in time order (stable on index for equal timestamps);
        // the duration cap drops late arrivals up front.
        let mut order: Vec<usize> = (0..queries.len()).collect();
        order.sort_by(|&a, &b| {
            arrivals_s[a]
                .partial_cmp(&arrivals_s[b])
                .unwrap()
                .then(a.cmp(&b))
        });
        let horizon_ns = self.cfg.duration_s.map(to_ns);
        let mut n_dropped = 0usize;
        for &qi in &order {
            let t = to_ns(arrivals_s[qi]);
            if horizon_ns.is_some_and(|h| t > h) {
                n_dropped += 1;
                continue;
            }
            heap.push(Ev {
                t,
                seq,
                kind: EvKind::Arrive(qi),
            });
            seq += 1;
        }

        let max_wait = Duration::from_secs_f64(self.cfg.max_wait_s);
        let mut nodes: Vec<Node> = self
            .sets
            .iter()
            .map(|s| Node {
                batcher: Batcher::new(&s.model_id, self.cfg.max_batch, max_wait),
                busy: false,
                ready: VecDeque::new(),
                next_timeout: None,
                stats: NodeStats {
                    model_id: s.model_id.clone(),
                    ..NodeStats::default()
                },
            })
            .collect();

        let mut arrive_ns: Vec<u64> = vec![0; queries.len()];
        let mut outcomes: Vec<QueryOutcome> = Vec::with_capacity(queries.len());

        // Start the next ready batch on an idle node: service time is the
        // slowest member's predicted runtime (lockstep batch execution).
        let try_start =
            |k: usize, t: u64, nodes: &mut Vec<Node>, heap: &mut BinaryHeap<Ev>, seq: &mut u64| {
                let node = &mut nodes[k];
                if node.busy {
                    return;
                }
                let Some(batch) = node.ready.pop_front() else {
                    return;
                };
                let members: Vec<usize> = batch.requests.iter().map(|r| r.id as usize).collect();
                let service_s = members
                    .iter()
                    .map(|&qi| {
                        let q = &queries[qi];
                        self.sets[k].runtime.predict(q.t_in as f64, q.t_out as f64)
                    })
                    .fold(0.0f64, f64::max)
                    .max(0.0);
                node.busy = true;
                heap.push(Ev {
                    t: t.saturating_add(to_ns(service_s)),
                    seq: *seq,
                    kind: EvKind::Complete {
                        node: k,
                        start: t,
                        members,
                    },
                });
                *seq += 1;
            };

        // Schedule (or refresh) the node's age-flush wakeup at the
        // batcher's deadline.
        let schedule_timeout =
            |k: usize, nodes: &mut Vec<Node>, heap: &mut BinaryHeap<Ev>, seq: &mut u64| {
                let node = &mut nodes[k];
                let Some(deadline) = node.batcher.deadline() else {
                    return;
                };
                let dl_ns = deadline.duration_since(anchor).as_nanos() as u64;
                if node.next_timeout != Some(dl_ns) {
                    node.next_timeout = Some(dl_ns);
                    heap.push(Ev {
                        t: dl_ns,
                        seq: *seq,
                        kind: EvKind::Timeout(k),
                    });
                    *seq += 1;
                }
            };

        while let Some(Ev { t, kind, .. }) = heap.pop() {
            match kind {
                EvKind::Arrive(qi) => {
                    let q = &queries[qi];
                    let k = policy.route(q);
                    debug_assert!(k < self.sets.len());
                    arrive_ns[qi] = t;
                    let req = Request {
                        id: qi as u64,
                        prompt: Vec::new(),
                        n_gen: q.t_out as usize,
                        submitted: at(t),
                    };
                    if let Some(batch) = nodes[k].batcher.push_at(req, at(t)) {
                        nodes[k].ready.push_back(batch);
                        try_start(k, t, &mut nodes, &mut heap, &mut seq);
                    } else {
                        schedule_timeout(k, &mut nodes, &mut heap, &mut seq);
                    }
                }
                EvKind::Timeout(k) => {
                    if nodes[k].next_timeout != Some(t) {
                        continue; // superseded by a size flush or later deadline
                    }
                    nodes[k].next_timeout = None;
                    if let Some(batch) = nodes[k].batcher.poll(at(t)) {
                        nodes[k].ready.push_back(batch);
                        try_start(k, t, &mut nodes, &mut heap, &mut seq);
                    }
                    schedule_timeout(k, &mut nodes, &mut heap, &mut seq);
                }
                EvKind::Complete {
                    node: k,
                    start,
                    members,
                } => {
                    let node = &mut nodes[k];
                    node.busy = false;
                    node.stats.batches += 1;
                    node.stats.queries += members.len() as u64;
                    node.stats.busy_s += ns_to_s(t - start);
                    for qi in members {
                        let q = &queries[qi];
                        let energy_j =
                            self.sets[k].energy.predict(q.t_in as f64, q.t_out as f64);
                        node.stats.energy_j += energy_j;
                        outcomes.push(QueryOutcome {
                            id: q.id,
                            model: k,
                            t_arrive: ns_to_s(arrive_ns[qi]),
                            t_start: ns_to_s(start),
                            t_complete: ns_to_s(t),
                            energy_j,
                        });
                    }
                    try_start(k, t, &mut nodes, &mut heap, &mut seq);
                }
            }
        }

        // Conservation invariant: every admitted arrival completed.
        let admitted = queries.len() - n_dropped;
        if outcomes.len() != admitted {
            anyhow::bail!(
                "simulator lost queries: {} admitted, {} completed",
                admitted,
                outcomes.len()
            );
        }
        for node in &nodes {
            debug_assert!(node.batcher.is_empty() && node.ready.is_empty() && !node.busy);
        }

        Ok(SimMetrics::from_outcomes(
            policy.kind().label().to_string(),
            self.arrival_label.clone(),
            self.seed,
            self.zeta,
            self.cfg.slo_s,
            n_dropped,
            policy.plan_stats(),
            nodes.into_iter().map(|n| n.stats).collect(),
            outcomes,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Normalizer;
    use crate::sim::PolicyKind;
    use crate::testkit::synthetic_pair as sets;

    fn q(id: u32, t_in: u32, t_out: u32) -> Query {
        Query { id, t_in, t_out }
    }

    fn norm(sets: &[ModelSet]) -> Normalizer {
        let probe: Vec<Query> = (1..50).map(|i| q(i, 10 * i, 20 * i)).collect();
        Normalizer::from_workload(sets, &probe)
    }

    fn greedy(s: &[ModelSet], zeta: f64) -> SimPolicy {
        SimPolicy::new(PolicyKind::Greedy, s, norm(s), zeta, None, 7).unwrap()
    }

    #[test]
    fn single_query_waits_out_the_age_trigger() {
        let s = sets();
        let cfg = SimConfig {
            max_batch: 8,
            max_wait_s: 0.5,
            ..SimConfig::default()
        };
        let queries = vec![q(0, 100, 100)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[1.0], &mut greedy(&s, 1.0))
            .unwrap();
        assert_eq!(m.n_queries, 1);
        let o = m.outcomes[0];
        // ζ=1 greedy routes to the energy-min model ("small").
        assert_eq!(o.model, 0);
        assert_eq!(o.t_arrive, 1.0);
        // Alone in the batcher: starts exactly at arrival + max_wait.
        assert!((o.t_start - 1.5).abs() < 1e-9, "t_start={}", o.t_start);
        let service = s[0].runtime.predict(100.0, 100.0);
        assert!(
            (o.t_complete - (1.5 + service)).abs() < 1e-6,
            "t_complete={}",
            o.t_complete
        );
        assert!((m.total_energy_j - s[0].energy.predict(100.0, 100.0)).abs() < 1e-9);
        assert_eq!(m.nodes[0].batches, 1);
        assert_eq!(m.nodes[1].batches, 0);
    }

    #[test]
    fn size_trigger_starts_immediately() {
        let s = sets();
        let cfg = SimConfig {
            max_batch: 2,
            max_wait_s: 10.0,
            ..SimConfig::default()
        };
        let queries = vec![q(0, 50, 50), q(1, 100, 100)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[0.0, 0.0], &mut greedy(&s, 1.0))
            .unwrap();
        // Both land on "small"; batch fills instantly → zero queue wait.
        assert_eq!(m.mean_queue_s, 0.0);
        assert_eq!(m.nodes[0].batches, 1);
        // Lockstep batch: both complete at the slower member's runtime.
        let slow = s[0].runtime.predict(100.0, 100.0);
        for o in &m.outcomes {
            assert!((o.t_complete - slow).abs() < 1e-6);
        }
    }

    #[test]
    fn busy_engine_queues_the_next_batch() {
        let s = sets();
        let cfg = SimConfig {
            max_batch: 1, // every query is its own batch
            max_wait_s: 10.0,
            ..SimConfig::default()
        };
        let queries = vec![q(0, 200, 400), q(1, 200, 400)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[0.0, 0.0], &mut greedy(&s, 1.0))
            .unwrap();
        let service = s[0].runtime.predict(200.0, 400.0);
        let mut by_id = m.outcomes.clone();
        by_id.sort_by_key(|o| o.id);
        // First batch runs [0, service); second starts when the engine
        // frees, so its queue wait is one full service time.
        assert!((by_id[0].t_start - 0.0).abs() < 1e-9);
        assert!((by_id[1].t_start - service).abs() < 1e-6);
        assert!((m.makespan_s - 2.0 * service).abs() < 1e-6);
        assert!((m.nodes[0].busy_s - 2.0 * service).abs() < 1e-6);
    }

    #[test]
    fn duration_cap_drops_late_arrivals() {
        let s = sets();
        let cfg = SimConfig {
            duration_s: Some(1.0),
            ..SimConfig::default()
        };
        let queries = vec![q(0, 10, 10), q(1, 10, 10), q(2, 10, 10)];
        let m = Simulator::new(&s, cfg)
            .run(&queries, &[0.5, 2.0, 1.0], &mut greedy(&s, 0.5))
            .unwrap();
        assert_eq!(m.n_queries, 2);
        assert_eq!(m.n_dropped, 1);
        let served: Vec<u32> = {
            let mut ids: Vec<u32> = m.outcomes.iter().map(|o| o.id).collect();
            ids.sort();
            ids
        };
        assert_eq!(served, vec![0, 2]);
    }

    #[test]
    fn conservation_across_random_streams() {
        use crate::testkit::{forall, Config};
        let s = sets();
        forall(Config::default().cases(30), |rng| {
            let n = rng.int_range(1, 120) as usize;
            let queries: Vec<Query> = (0..n)
                .map(|i| {
                    q(
                        i as u32,
                        rng.int_range(1, 500) as u32,
                        rng.int_range(1, 500) as u32,
                    )
                })
                .collect();
            let arrivals: Vec<f64> = (0..n).map(|_| rng.range(0.0, 3.0)).collect();
            let cfg = SimConfig {
                max_batch: rng.int_range(1, 6) as usize,
                max_wait_s: rng.range(0.0, 0.2),
                ..SimConfig::default()
            };
            let mut policy = greedy(&s, rng.range(0.0, 1.0));
            let m = Simulator::new(&s, cfg)
                .run(&queries, &arrivals, &mut policy)
                .unwrap();
            assert_eq!(m.n_queries, n);
            // Each query served exactly once.
            let mut ids: Vec<u32> = m.outcomes.iter().map(|o| o.id).collect();
            ids.sort();
            assert_eq!(ids, (0..n as u32).collect::<Vec<_>>());
            // Causality: arrive ≤ start ≤ complete for every query.
            for o in &m.outcomes {
                assert!(o.t_arrive <= o.t_start + 1e-12);
                assert!(o.t_start <= o.t_complete + 1e-12);
            }
            // Energy is conserved: node totals equal the outcome sum.
            let node_total: f64 = m.nodes.iter().map(|nd| nd.energy_j).sum();
            assert!((node_total - m.total_energy_j).abs() < 1e-6);
        });
    }

    #[test]
    fn mismatched_arrival_lengths_error() {
        let s = sets();
        let err = Simulator::new(&s, SimConfig::default())
            .run(&[q(0, 1, 1)], &[0.0, 1.0], &mut greedy(&s, 0.5))
            .unwrap_err();
        assert!(err.to_string().contains("arrival"), "{err}");
    }
}
