//! The policy-comparison harness: one seeded arrival trace, several
//! routing policies, directly comparable metrics.
//!
//! Every policy replays the *same* timestamped workload on the *same*
//! cluster configuration — only the routing decisions differ — so
//! energy/latency/SLO deltas are attributable to the policy alone. This
//! is the simulated analogue of the paper's Fig. 3 baseline comparison,
//! with queueing and batching in the loop.

use super::metrics::SimMetrics;
use super::policy::{PolicyKind, SimPolicy};
use super::simulator::{SimConfig, Simulator};
use crate::models::{ModelSet, Normalizer};
use crate::plan::Plan;
use crate::util::Json;
use crate::workload::Query;

/// Everything a comparison run shares across policies.
pub struct CompareSpec<'a> {
    pub sets: &'a [ModelSet],
    pub norm: Normalizer,
    pub zeta: f64,
    /// required when the kinds include [`PolicyKind::Plan`]
    pub plan: Option<&'a Plan>,
    pub seed: u64,
    pub cfg: SimConfig,
    /// arrival-process label recorded in each artifact
    pub arrival_label: String,
}

/// Run each policy over the identical `(queries, arrivals_s)` trace.
/// Returns one [`SimMetrics`] per kind, in the given order.
pub fn compare(
    spec: &CompareSpec<'_>,
    queries: &[Query],
    arrivals_s: &[f64],
    kinds: &[PolicyKind],
) -> anyhow::Result<Vec<SimMetrics>> {
    let sim = Simulator::new(spec.sets, spec.cfg).labeled(
        &spec.arrival_label,
        spec.seed,
        spec.zeta,
    );
    kinds
        .iter()
        .map(|&kind| {
            let mut policy = SimPolicy::new(
                kind,
                spec.sets,
                spec.norm,
                spec.zeta,
                spec.plan,
                spec.seed,
            )?;
            sim.run(queries, arrivals_s, &mut policy)
        })
        .collect()
}

/// Bundle per-policy artifacts into one JSON document: a `policies`
/// array with one metrics object per policy, in run order.
pub fn comparison_to_json(rows: &[SimMetrics]) -> Json {
    Json::obj(vec![
        ("format", Json::str("ecoserve.sim-comparison")),
        ("version", Json::num(1.0)),
        (
            "policies",
            Json::arr(rows.iter().map(|m| m.to_json())),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::synthetic_trio as sets;
    use crate::util::Rng;

    #[test]
    fn baselines_share_the_trace_and_differ_only_in_routing() {
        let s = sets();
        let mut rng = Rng::new(5);
        let queries: Vec<Query> = (0..40)
            .map(|i| Query {
                id: i,
                t_in: rng.int_range(1, 300) as u32,
                t_out: rng.int_range(1, 300) as u32,
            })
            .collect();
        let arrivals: Vec<f64> = {
            let mut t = 0.0;
            (0..40)
                .map(|_| {
                    t += rng.exponential(20.0);
                    t
                })
                .collect()
        };
        let spec = CompareSpec {
            sets: &s,
            norm: Normalizer::from_workload(&s, &queries),
            zeta: 1.0,
            plan: None,
            seed: 9,
            cfg: SimConfig::default(),
            arrival_label: "poisson:20".to_string(),
        };
        let kinds = [
            PolicyKind::Greedy,
            PolicyKind::RoundRobin,
            PolicyKind::Random,
        ];
        let rows = compare(&spec, &queries, &arrivals, &kinds).unwrap();
        assert_eq!(rows.len(), 3);
        for (row, kind) in rows.iter().zip(kinds) {
            assert_eq!(row.policy, kind.label());
            assert_eq!(row.n_queries, 40);
        }
        // ζ=1 greedy minimizes per-query energy → no baseline beats it
        // without capacity constraints in the way.
        assert!(rows[0].total_energy_j <= rows[1].total_energy_j + 1e-9);
        assert!(rows[0].total_energy_j <= rows[2].total_energy_j + 1e-9);
        let json = comparison_to_json(&rows).to_string_pretty();
        assert!(json.contains("ecoserve.sim-comparison"));
        assert!(json.contains("round-robin"));
    }

    #[test]
    fn plan_kind_without_plan_errors() {
        let s = sets();
        let queries = vec![Query { id: 0, t_in: 5, t_out: 5 }];
        let spec = CompareSpec {
            sets: &s,
            norm: Normalizer::from_workload(&s, &queries),
            zeta: 0.5,
            plan: None,
            seed: 1,
            cfg: SimConfig::default(),
            arrival_label: "poisson:1".to_string(),
        };
        assert!(compare(&spec, &queries, &[0.0], &[PolicyKind::Plan]).is_err());
    }
}
