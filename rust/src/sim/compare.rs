//! The policy-comparison harness: one seeded arrival trace (or several
//! replicate traces), several routing policies, directly comparable
//! metrics — fanned out across threads, merged deterministically.
//!
//! Every policy replays the *same* timestamped workload on the *same*
//! cluster configuration — only the routing decisions differ — so
//! energy/latency/SLO deltas are attributable to the policy alone. This
//! is the simulated analogue of the paper's Fig. 3 baseline comparison,
//! with queueing and batching in the loop. [`compare_replicated`] extends
//! it with `--seeds N` replication: N independent arrival draws per
//! policy, summarized with 95% confidence intervals, so a policy gap can
//! be told from arrival-process luck.
//!
//! # Parallelism vs determinism
//!
//! Each (policy, seed) run is a pure function of its inputs — the
//! simulator shares nothing mutable across runs — so the harness fans the
//! policy×seed grid across `std::thread` scoped workers and writes each
//! result into its preassigned slot. Results are then read back in fixed
//! (policy, seed) order, making the comparison artifact byte-stable no
//! matter how the OS schedules the workers; arrival sequences are sampled
//! *once per seed* before the fan-out, so compared policies see the
//! identical trace by construction (and the sampler runs once, not once
//! per policy).

use super::arrival::{ARRIVAL_SEED_SALT, ArrivalProcess};
use super::failure::FailureScript;
use super::hazard::Hazard;
use super::metrics::SimMetrics;
use super::policy::{PolicyKind, SimPolicy};
use super::simulator::{Memo, ResilienceConfig, SimConfig, Simulator};
use crate::control::ControlConfig;
use crate::models::{ModelSet, Normalizer};
use crate::plan::Plan;
use crate::stats::{ci_half_width, mean};
use crate::util::{Json, Rng};
use crate::workload::Query;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Everything a comparison run shares across policies.
pub struct CompareSpec<'a> {
    pub sets: &'a [ModelSet],
    pub norm: Normalizer,
    pub zeta: f64,
    /// required when the kinds include [`PolicyKind::Plan`]
    pub plan: Option<&'a Plan>,
    /// base seed; replicate `i` runs under `seed + i`
    pub seed: u64,
    pub cfg: SimConfig,
    /// arrival-process label recorded in each artifact
    pub arrival_label: String,
    /// control-plane configuration: required when the kinds include
    /// [`PolicyKind::Replan`]; its carbon signal (when set) also turns on
    /// carbon metering for *every* policy in the grid, so realized gCO₂
    /// is directly comparable across rows
    pub control: Option<ControlConfig>,
    /// per-model replica counts (`--replicas`); `None` hosts each model
    /// on a single node
    pub replicas: Option<&'a [usize]>,
    /// failure/elasticity scenario (`--failures`) replayed identically
    /// under every (policy, seed) in the grid, so degradation under the
    /// *same* outage is attributable to the policy alone
    pub failures: Option<&'a FailureScript>,
    /// failure-*process* ensemble mode (`--hazard`): replicate `i` draws
    /// one outage script from the process under `hazard_seed + i` and
    /// replays it under every policy at that seed — so across the
    /// `--seeds N` grid each policy faces the same N outage draws, and
    /// cross-seed CIs average over the process, not one lucky script.
    /// Mutually exclusive with `failures`.
    pub hazard: Option<&'a Hazard>,
    /// base seed for hazard generation (`--hazard-seed`); deliberately
    /// separate from `seed` so outage draws can be held fixed while
    /// arrival draws vary, and vice versa
    pub hazard_seed: u64,
    /// required when the kinds include [`PolicyKind::Resilient`]: the
    /// N+k plan ([`PlanSession::plan_resilient`]) that policy follows
    ///
    /// [`PlanSession::plan_resilient`]: crate::plan::PlanSession::plan_resilient
    pub resilient_plan: Option<&'a Plan>,
    /// request-level survival (`--retry-budget`/`--hedge-ms`/…): applied
    /// to every policy in the grid, so availability deltas are
    /// attributable to routing, not to one row retrying harder
    pub resilience: Option<ResilienceConfig>,
}

/// Where a replicate's arrival timestamps come from.
pub enum Arrivals<'a> {
    /// one fixed timestamp vector (trace replay) shared by every seed
    Fixed(&'a [f64]),
    /// a fresh sequence per seed, sampled from the process
    Sampled(ArrivalProcess),
}

/// Run each policy over the identical `(queries, arrivals_s)` trace.
/// Returns one [`SimMetrics`] per kind, in the given order.
pub fn compare(
    spec: &CompareSpec<'_>,
    queries: &[Query],
    arrivals_s: &[f64],
    kinds: &[PolicyKind],
) -> anyhow::Result<Vec<SimMetrics>> {
    let grid = compare_replicated(spec, queries, Arrivals::Fixed(arrivals_s), kinds, 1)?;
    Ok(grid.into_iter().map(|mut runs| runs.remove(0)).collect())
}

/// The `--seeds N` replication harness: every policy × every replicate
/// seed, in parallel. Returns `result[kind_index][seed_index]`, where
/// replicate `i` runs under seed `spec.seed + i` — its arrival sequence
/// (for [`Arrivals::Sampled`]) drawn once from
/// `Rng::new(seed_i ^ ARRIVAL_SEED_SALT)` and shared across all kinds.
pub fn compare_replicated(
    spec: &CompareSpec<'_>,
    queries: &[Query],
    arrivals: Arrivals<'_>,
    kinds: &[PolicyKind],
    n_seeds: usize,
) -> anyhow::Result<Vec<Vec<SimMetrics>>> {
    anyhow::ensure!(n_seeds >= 1, "need at least one replicate seed");
    anyhow::ensure!(!kinds.is_empty(), "need at least one policy to compare");
    anyhow::ensure!(
        spec.failures.is_none() || spec.hazard.is_none(),
        "give either a fixed failure script (--failures) or a hazard process \
         (--hazard), not both"
    );
    let seeds: Vec<u64> = (0..n_seeds as u64)
        .map(|i| spec.seed.wrapping_add(i))
        .collect();

    // Arrival sequences once per seed, before the fan-out.
    let sampled: Vec<Vec<f64>> = match &arrivals {
        Arrivals::Fixed(_) => Vec::new(),
        Arrivals::Sampled(process) => seeds
            .iter()
            .map(|&s| process.times(queries.len(), &mut Rng::new(s ^ ARRIVAL_SEED_SALT)))
            .collect::<anyhow::Result<_>>()?,
    };
    let per_seed_times: Vec<&[f64]> = match &arrivals {
        Arrivals::Fixed(times) => vec![*times; n_seeds],
        Arrivals::Sampled(_) => sampled.iter().map(Vec::as_slice).collect(),
    };

    // Hazard-ensemble mode: one outage script per replicate seed, drawn
    // before the fan-out and shared by every policy at that seed (the
    // horizon covers the seed's whole arrival window, so the process can
    // strike any arriving query).
    let hazard_scripts: Vec<FailureScript> = match spec.hazard {
        None => Vec::new(),
        Some(h) => {
            let counts: Vec<usize> = match spec.replicas {
                Some(c) => c.to_vec(),
                None => vec![1; spec.sets.len()],
            };
            per_seed_times
                .iter()
                .enumerate()
                .map(|(si, times)| {
                    let horizon_s = times.last().copied().unwrap_or(0.0) + 1.0;
                    h.generate(&counts, horizon_s, spec.hazard_seed.wrapping_add(si as u64))
                })
                .collect::<anyhow::Result<_>>()?
        }
    };
    // One shape memo for the whole grid: it depends only on (sets,
    // queries), so per-task rebuilding would repeat the O(|Q|) bucketing
    // kinds×seeds times (and allocate one shape map per worker).
    let memo = spec.cfg.memoize.then(|| Memo::build(spec.sets, queries));

    // Fan the policy×seed grid over a worker pool; each task writes its
    // preassigned slot, so completion order never reaches the output.
    type Slot = Mutex<Option<anyhow::Result<SimMetrics>>>;
    let tasks = kinds.len() * n_seeds;
    let slots: Vec<Slot> = (0..tasks).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(tasks);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= tasks {
                    break;
                }
                let (ki, si) = (i / n_seeds, i % n_seeds);
                let seed = seeds[si];
                // The resilient policy follows its own N+k plan; every
                // other plan-follower uses the static one.
                let plan = if kinds[ki] == PolicyKind::Resilient {
                    spec.resilient_plan
                } else {
                    spec.plan
                };
                let run = SimPolicy::new(
                    kinds[ki],
                    spec.sets,
                    spec.norm,
                    spec.zeta,
                    plan,
                    seed,
                    spec.control.as_ref(),
                )
                .and_then(|mut policy| {
                    let mut sim = Simulator::new(spec.sets, spec.cfg)
                        .labeled(&spec.arrival_label, seed, spec.zeta);
                    if let Some(counts) = spec.replicas {
                        sim = sim.with_replicas(counts)?;
                    }
                    let script = if spec.hazard.is_some() {
                        Some(&hazard_scripts[si])
                    } else {
                        spec.failures
                    };
                    if let Some(script) = script {
                        sim = sim.with_failures(script);
                    }
                    if let Some(rc) = spec.resilience {
                        sim = sim.with_resilience(rc)?;
                    }
                    if let Some(carbon) =
                        spec.control.as_ref().and_then(|c| c.carbon.as_ref())
                    {
                        sim = sim.with_carbon(carbon.clone());
                    }
                    sim.run_with_memo(
                        queries,
                        per_seed_times[si],
                        &mut policy,
                        memo.as_ref(),
                    )
                });
                *slots[i].lock().unwrap() = Some(run);
            });
        }
    });

    // Deterministic merge: fixed (policy, seed) order.
    let mut slots = slots.into_iter();
    let mut grid = Vec::with_capacity(kinds.len());
    for _ in kinds {
        let mut runs = Vec::with_capacity(n_seeds);
        for _ in 0..n_seeds {
            let slot = slots.next().unwrap().into_inner().unwrap();
            runs.push(slot.expect("every task stores a result before joining")?);
        }
        grid.push(runs);
    }
    Ok(grid)
}

/// Bundle per-policy artifacts into one JSON document: a `policies`
/// array with one metrics object per policy, in run order (the
/// single-seed layout; see [`replicated_to_json`] for `--seeds N`).
pub fn comparison_to_json(rows: &[SimMetrics]) -> Json {
    Json::obj(vec![
        ("format", Json::str("ecoserve.sim-comparison")),
        ("version", Json::num(6.0)),
        (
            "policies",
            Json::arr(rows.iter().map(|m| m.to_json())),
        ),
    ])
}

/// The `--seeds N` comparison artifact: per policy, all replicate runs in
/// seed order plus a cross-seed summary (means and 95% Student-t
/// confidence half-widths) once there are ≥ 2 replicates.
pub fn replicated_to_json(grid: &[Vec<SimMetrics>]) -> Json {
    let seeds: Vec<Json> = grid
        .first()
        .map(|runs| runs.iter().map(|m| Json::str(m.seed.to_string())).collect())
        .unwrap_or_default();
    Json::obj(vec![
        ("format", Json::str("ecoserve.sim-comparison")),
        ("version", Json::num(6.0)),
        ("seeds", Json::Arr(seeds)),
        (
            "policies",
            Json::arr(grid.iter().map(|runs| {
                let mut fields = vec![
                    (
                        "policy",
                        Json::str(runs.first().map(|m| m.policy.clone()).unwrap_or_default()),
                    ),
                    ("runs", Json::arr(runs.iter().map(|m| m.to_json()))),
                ];
                if runs.len() >= 2 {
                    let series = |f: fn(&SimMetrics) -> f64| -> Vec<f64> {
                        runs.iter().map(f).collect()
                    };
                    let stat = |xs: &[f64]| {
                        Json::obj(vec![
                            ("mean", Json::num(mean(xs))),
                            ("ci95", Json::num(ci_half_width(xs, 0.95))),
                        ])
                    };
                    let mut summary = vec![
                        ("n_seeds", Json::num(runs.len() as f64)),
                        (
                            "total_energy_j",
                            stat(&series(|m| m.total_energy_j)),
                        ),
                        ("mean_latency_s", stat(&series(|m| m.mean_latency_s))),
                        ("p95_latency_s", stat(&series(|m| m.p95_latency_s))),
                        ("p95_ttft_s", stat(&series(|m| m.p95_ttft_s))),
                        ("p95_tpot_s", stat(&series(|m| m.p95_tpot_s))),
                        ("slo_attainment", stat(&series(|m| m.slo_attainment))),
                        ("availability", stat(&series(|m| m.availability))),
                        ("goodput_qps", stat(&series(|m| m.goodput_qps))),
                        ("makespan_s", stat(&series(|m| m.makespan_s))),
                    ];
                    // Realized carbon, when every replicate was metered
                    // (carbon-aware comparison runs).
                    if runs.iter().all(|m| m.carbon.is_some()) {
                        summary.push((
                            "total_carbon_g",
                            stat(&series(|m| {
                                m.carbon.as_ref().map_or(0.0, |c| c.total_g)
                            })),
                        ));
                    }
                    fields.push(("summary", Json::obj(summary)));
                }
                Json::obj(fields)
            })),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::synthetic_trio as sets;

    #[test]
    fn baselines_share_the_trace_and_differ_only_in_routing() {
        let s = sets();
        let mut rng = Rng::new(5);
        let queries: Vec<Query> = (0..40)
            .map(|i| Query {
                id: i,
                t_in: rng.int_range(1, 300) as u32,
                t_out: rng.int_range(1, 300) as u32,
            })
            .collect();
        let arrivals: Vec<f64> = {
            let mut t = 0.0;
            (0..40)
                .map(|_| {
                    t += rng.exponential(20.0);
                    t
                })
                .collect()
        };
        let spec = CompareSpec {
            sets: &s,
            norm: Normalizer::from_workload(&s, &queries),
            zeta: 1.0,
            plan: None,
            seed: 9,
            cfg: SimConfig::default(),
            arrival_label: "poisson:20".to_string(),
            control: None,
            replicas: None,
            failures: None,
            hazard: None,
            hazard_seed: 0,
            resilient_plan: None,
            resilience: None,
        };
        let kinds = [
            PolicyKind::Greedy,
            PolicyKind::RoundRobin,
            PolicyKind::Random,
        ];
        let rows = compare(&spec, &queries, &arrivals, &kinds).unwrap();
        assert_eq!(rows.len(), 3);
        for (row, kind) in rows.iter().zip(kinds) {
            assert_eq!(row.policy, kind.label());
            assert_eq!(row.n_queries, 40);
        }
        // ζ=1 greedy minimizes per-query energy → no baseline beats it
        // without capacity constraints in the way.
        assert!(rows[0].total_energy_j <= rows[1].total_energy_j + 1e-9);
        assert!(rows[0].total_energy_j <= rows[2].total_energy_j + 1e-9);
        let json = comparison_to_json(&rows).to_string_pretty();
        assert!(json.contains("ecoserve.sim-comparison"));
        assert!(json.contains("round-robin"));
    }

    #[test]
    fn replication_runs_each_seed_once_and_summarizes() {
        let s = sets();
        let queries: Vec<Query> = (0..30)
            .map(|i| Query {
                id: i,
                t_in: 1 + 11 * (i % 5),
                t_out: 1 + 7 * (i % 3),
            })
            .collect();
        let spec = CompareSpec {
            sets: &s,
            norm: Normalizer::from_workload(&s, &queries),
            zeta: 0.7,
            plan: None,
            seed: 100,
            cfg: SimConfig::default(),
            arrival_label: "poisson:25".to_string(),
            control: None,
            replicas: None,
            failures: None,
            hazard: None,
            hazard_seed: 0,
            resilient_plan: None,
            resilience: None,
        };
        let kinds = [PolicyKind::Greedy, PolicyKind::RoundRobin];
        let grid = compare_replicated(
            &spec,
            &queries,
            Arrivals::Sampled(ArrivalProcess::Poisson { rate: 25.0 }),
            &kinds,
            3,
        )
        .unwrap();
        assert_eq!(grid.len(), 2);
        for (runs, kind) in grid.iter().zip(kinds) {
            assert_eq!(runs.len(), 3);
            for (i, m) in runs.iter().enumerate() {
                assert_eq!(m.policy, kind.label());
                assert_eq!(m.seed, 100 + i as u64);
                assert_eq!(m.n_queries, 30);
            }
        }
        // Replicates share arrivals across policies: same seed ⇒ same
        // makespan-irrelevant inputs, so greedy and round-robin replicate
        // i agree on n and arrival label but differ in routing.
        let json = replicated_to_json(&grid).to_string_pretty();
        assert!(json.contains("\"seeds\""), "{json}");
        assert!(json.contains("\"100\"") && json.contains("\"102\""), "{json}");
        assert!(json.contains("\"summary\""), "{json}");
        assert!(json.contains("\"ci95\""), "{json}");
        // Different seeds actually drew different arrival sequences.
        assert_ne!(
            grid[0][0].to_json().to_string_pretty(),
            grid[0][1].to_json().to_string_pretty()
        );
    }

    #[test]
    fn parallel_merge_is_byte_stable() {
        let s = sets();
        let queries: Vec<Query> = (0..60)
            .map(|i| Query {
                id: i,
                t_in: 1 + (i % 7) * 13,
                t_out: 1 + (i % 4) * 31,
            })
            .collect();
        let run = || {
            let spec = CompareSpec {
                sets: &s,
                norm: Normalizer::from_workload(&s, &queries),
                zeta: 0.5,
                plan: None,
                seed: 7,
                cfg: SimConfig::default(),
                arrival_label: "gamma:40:4".to_string(),
                control: None,
                replicas: None,
                failures: None,
                hazard: None,
                hazard_seed: 0,
                resilient_plan: None,
                resilience: None,
            };
            let grid = compare_replicated(
                &spec,
                &queries,
                Arrivals::Sampled(ArrivalProcess::GammaBurst { rate: 40.0, cv2: 4.0 }),
                &[PolicyKind::Greedy, PolicyKind::RoundRobin, PolicyKind::Random],
                3,
            )
            .unwrap();
            replicated_to_json(&grid).to_string_pretty()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn plan_kind_without_plan_errors() {
        let s = sets();
        let queries = vec![Query { id: 0, t_in: 5, t_out: 5 }];
        let spec = CompareSpec {
            sets: &s,
            norm: Normalizer::from_workload(&s, &queries),
            zeta: 0.5,
            plan: None,
            seed: 1,
            cfg: SimConfig::default(),
            arrival_label: "poisson:1".to_string(),
            control: None,
            replicas: None,
            failures: None,
            hazard: None,
            hazard_seed: 0,
            resilient_plan: None,
            resilience: None,
        };
        assert!(compare(&spec, &queries, &[0.0], &[PolicyKind::Plan]).is_err());
        // Replan likewise refuses to run without a control configuration,
        // and resilient without its N+k plan.
        assert!(compare(&spec, &queries, &[0.0], &[PolicyKind::Replan]).is_err());
        assert!(compare(&spec, &queries, &[0.0], &[PolicyKind::Resilient]).is_err());
    }

    #[test]
    fn failure_scenario_replays_identically_under_every_policy() {
        let s = sets();
        let queries: Vec<Query> = (0..40)
            .map(|i| Query {
                id: i,
                t_in: 1 + 17 * (i % 4),
                t_out: 1 + 23 * (i % 3),
            })
            .collect();
        let arrivals: Vec<f64> = (0..40).map(|i| 0.05 * i as f64).collect();
        let script = FailureScript::from_jsonl(
            r#"
            {"t": 0.3, "model": 0, "replica": 1, "kind": "kill"}
            {"t": 0.8, "model": 0, "replica": 1, "kind": "join", "warmup": 0.1}
            "#,
        )
        .unwrap();
        let replicas = [2usize, 1, 1];
        let run = || {
            let spec = CompareSpec {
                sets: &s,
                norm: Normalizer::from_workload(&s, &queries),
                zeta: 0.5,
                plan: None,
                seed: 3,
                cfg: SimConfig::default(),
                arrival_label: "trace".to_string(),
                control: None,
                replicas: Some(&replicas),
                failures: Some(&script),
                hazard: None,
                hazard_seed: 0,
                resilient_plan: None,
                resilience: None,
            };
            compare(
                &spec,
                &queries,
                &arrivals,
                &[PolicyKind::Greedy, PolicyKind::RoundRobin],
            )
            .unwrap()
        };
        let rows = run();
        for row in &rows {
            // Same outage for every policy: same scenario label, same
            // replica fleet, nothing lost.
            assert_eq!(row.scenario, "chaos:2");
            assert_eq!(row.n_queries, 40);
            assert_eq!(row.nodes.len(), 4);
        }
        // And the whole comparison artifact is byte-stable under replay.
        assert_eq!(
            comparison_to_json(&rows).to_string_pretty(),
            comparison_to_json(&run()).to_string_pretty()
        );
    }

    #[test]
    fn carbon_control_meters_every_policy_in_the_grid() {
        let s = sets();
        let queries: Vec<Query> = (0..30)
            .map(|i| Query {
                id: i,
                t_in: 1 + 11 * (i % 5),
                t_out: 1 + 7 * (i % 3),
            })
            .collect();
        let control = ControlConfig {
            replan_every: 8,
            slo_trigger_s: None,
            carbon: Some(crate::control::CarbonConfig::typical(0.2, 0.8)),
        };
        let spec = CompareSpec {
            sets: &s,
            norm: Normalizer::from_workload(&s, &queries),
            zeta: 0.5,
            plan: None,
            seed: 11,
            cfg: SimConfig::default(),
            arrival_label: "poisson:25".to_string(),
            control: Some(control),
            replicas: None,
            failures: None,
            hazard: None,
            hazard_seed: 0,
            resilient_plan: None,
            resilience: None,
        };
        let kinds = [PolicyKind::Replan, PolicyKind::Greedy];
        let grid = compare_replicated(
            &spec,
            &queries,
            Arrivals::Sampled(ArrivalProcess::Poisson { rate: 25.0 }),
            &kinds,
            2,
        )
        .unwrap();
        for (runs, kind) in grid.iter().zip(kinds) {
            for m in runs {
                assert_eq!(m.policy, kind.label());
                let carbon = m.carbon.as_ref().expect("every policy is metered");
                assert!(carbon.total_g > 0.0);
            }
        }
        // Only the replan rows carry control counters.
        assert!(grid[0].iter().all(|m| m.replan_stats.is_some()));
        assert!(grid[1].iter().all(|m| m.replan_stats.is_none()));
        let json = replicated_to_json(&grid).to_string_pretty();
        assert!(json.contains("\"total_carbon_g\""), "{json}");
        assert!(json.contains("\"version\": 6"), "{json}");
    }

    #[test]
    fn hazard_ensemble_is_byte_stable_and_shared_across_policies() {
        let s = sets();
        let queries: Vec<Query> = (0..50)
            .map(|i| Query {
                id: i,
                t_in: 1 + 13 * (i % 5),
                t_out: 1 + 11 * (i % 4),
            })
            .collect();
        let hazard = Hazard::parse("mtbf:0.4:0.1").unwrap();
        let replicas = [2usize, 2, 1];
        let run = || {
            let spec = CompareSpec {
                sets: &s,
                norm: Normalizer::from_workload(&s, &queries),
                zeta: 0.5,
                plan: None,
                seed: 21,
                cfg: SimConfig::default(),
                arrival_label: "poisson:30".to_string(),
                control: None,
                replicas: Some(&replicas),
                failures: None,
                hazard: Some(&hazard),
                hazard_seed: 77,
                resilient_plan: None,
                resilience: Some(ResilienceConfig::default()),
            };
            compare_replicated(
                &spec,
                &queries,
                Arrivals::Sampled(ArrivalProcess::Poisson { rate: 30.0 }),
                &[PolicyKind::Greedy, PolicyKind::RoundRobin],
                3,
            )
            .unwrap()
        };
        let grid = run();
        // Byte-identical under replay — the ensemble is a pure function
        // of (hazard, fleet, seeds).
        assert_eq!(
            replicated_to_json(&grid).to_string_pretty(),
            replicated_to_json(&run()).to_string_pretty()
        );
        for runs in &grid {
            for m in runs {
                // Every run carries the hazard spelling as its scenario
                // and conserves the workload across retries/failures.
                assert_eq!(m.scenario, "mtbf:0.4:0.1");
                assert_eq!(m.n_queries + m.n_failed, 50);
                assert!(m.availability > 0.0 && m.availability <= 1.0);
            }
        }
        // The two policies at one seed face the same outage draw, and
        // different seeds draw different scripts: downtime is a property
        // of the script alone, so it matches across policies per seed.
        for si in 0..3 {
            let downtime = |m: &SimMetrics| -> f64 {
                m.nodes.iter().map(|n| n.downtime_s).sum()
            };
            assert!((downtime(&grid[0][si]) - downtime(&grid[1][si])).abs() < 1e-9);
        }
        let json = replicated_to_json(&grid).to_string_pretty();
        assert!(json.contains("\"availability\""), "{json}");
        assert!(json.contains("\"goodput_qps\""), "{json}");
    }

    #[test]
    fn hazard_and_fixed_failures_are_mutually_exclusive() {
        let s = sets();
        let queries = vec![Query { id: 0, t_in: 5, t_out: 5 }];
        let hazard = Hazard::parse("mtbf:10:1").unwrap();
        let script = FailureScript::from_jsonl(
            r#"{"t": 0.5, "model": 0, "replica": 0, "kind": "drain"}"#,
        )
        .unwrap();
        let spec = CompareSpec {
            sets: &s,
            norm: Normalizer::from_workload(&s, &queries),
            zeta: 0.5,
            plan: None,
            seed: 1,
            cfg: SimConfig::default(),
            arrival_label: "trace".to_string(),
            control: None,
            replicas: None,
            failures: Some(&script),
            hazard: Some(&hazard),
            hazard_seed: 0,
            resilient_plan: None,
            resilience: None,
        };
        let err = compare(&spec, &queries, &[0.0], &[PolicyKind::Greedy])
            .unwrap_err()
            .to_string();
        assert!(err.contains("not both"), "{err}");
    }
}
