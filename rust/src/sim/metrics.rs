//! Per-query and per-node accounting of a simulated serving run, and its
//! deterministic JSON artifact.
//!
//! The JSON layout is stable by construction: objects serialize through
//! [`Json`] (BTreeMap-backed, keys sorted), floats use Rust's shortest
//! round-trip formatting, and every value derives from virtual-time
//! arithmetic — so equal `(workload, policy, seed, config)` runs emit
//! byte-identical artifacts. CI diffs two runs to enforce this.

use crate::stats::quantile;
use crate::util::Json;

/// Lifecycle of one simulated query (all times in virtual seconds from
/// simulation start).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueryOutcome {
    pub id: u32,
    /// index of the serving model/node
    pub model: usize,
    pub t_arrive: f64,
    /// batch execution start (arrival + queue + batching wait)
    pub t_start: f64,
    pub t_complete: f64,
    /// predicted energy attributed to this query (Eq. 6 at its shape)
    pub energy_j: f64,
}

impl QueryOutcome {
    pub fn latency_s(&self) -> f64 {
        self.t_complete - self.t_arrive
    }

    pub fn queue_s(&self) -> f64 {
        self.t_start - self.t_arrive
    }
}

/// Accumulated counters for one simulated node (one hosted model).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeStats {
    pub model_id: String,
    pub queries: u64,
    pub batches: u64,
    pub energy_j: f64,
    /// total virtual time the node's engine was executing batches
    pub busy_s: f64,
}

impl NodeStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.queries as f64 / self.batches as f64
    }
}

/// Aggregate metrics of one simulated run.
#[derive(Debug, Clone, PartialEq)]
pub struct SimMetrics {
    pub policy: String,
    pub arrival: String,
    pub seed: u64,
    pub zeta: f64,
    /// queries served (arrivals inside the duration window)
    pub n_queries: usize,
    /// arrivals dropped by the `--duration` cap
    pub n_dropped: usize,
    /// last completion time (virtual seconds)
    pub makespan_s: f64,
    pub total_energy_j: f64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub max_latency_s: f64,
    pub mean_queue_s: f64,
    /// latency SLO the attainment fraction is measured against
    pub slo_s: f64,
    /// fraction of queries with latency ≤ `slo_s`
    pub slo_attainment: f64,
    /// (plan-followed, fallback) router decisions, plan policy only
    pub plan_decisions: Option<(u64, u64)>,
    pub nodes: Vec<NodeStats>,
    /// per-query lifecycle records (kept out of the JSON artifact)
    pub outcomes: Vec<QueryOutcome>,
}

impl SimMetrics {
    /// Aggregate raw recordings into the metrics artifact.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_outcomes(
        policy: String,
        arrival: String,
        seed: u64,
        zeta: f64,
        slo_s: f64,
        n_dropped: usize,
        plan_decisions: Option<(u64, u64)>,
        nodes: Vec<NodeStats>,
        outcomes: Vec<QueryOutcome>,
    ) -> SimMetrics {
        let n = outcomes.len();
        let latencies: Vec<f64> = outcomes.iter().map(QueryOutcome::latency_s).collect();
        let mean = |xs: &[f64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let q = |p: f64| {
            if latencies.is_empty() {
                0.0
            } else {
                quantile(&latencies, p)
            }
        };
        let queue: Vec<f64> = outcomes.iter().map(QueryOutcome::queue_s).collect();
        SimMetrics {
            policy,
            arrival,
            seed,
            zeta,
            n_queries: n,
            n_dropped,
            makespan_s: outcomes
                .iter()
                .map(|o| o.t_complete)
                .fold(0.0f64, f64::max),
            total_energy_j: outcomes.iter().map(|o| o.energy_j).sum(),
            mean_latency_s: mean(&latencies),
            p50_latency_s: q(0.5),
            p95_latency_s: q(0.95),
            max_latency_s: latencies.iter().copied().fold(0.0f64, f64::max),
            mean_queue_s: mean(&queue),
            slo_s,
            slo_attainment: if n == 0 {
                0.0
            } else {
                latencies.iter().filter(|&&l| l <= slo_s).count() as f64 / n as f64
            },
            plan_decisions,
            nodes,
            outcomes,
        }
    }

    /// Mean node utilization: busy time over makespan, averaged over
    /// nodes. Zero on an empty run.
    pub fn mean_utilization(&self) -> f64 {
        if self.makespan_s <= 0.0 || self.nodes.is_empty() {
            return 0.0;
        }
        self.nodes
            .iter()
            .map(|nd| nd.busy_s / self.makespan_s)
            .sum::<f64>()
            / self.nodes.len() as f64
    }

    /// The deterministic metrics artifact (aggregates only; per-query
    /// outcomes stay in memory).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("format", Json::str("ecoserve.sim-metrics")),
            ("version", Json::num(1.0)),
            ("policy", Json::str(self.policy.clone())),
            ("arrival", Json::str(self.arrival.clone())),
            // As a decimal string: the f64-backed Json would round seeds
            // above 2^53 and the artifact could no longer reproduce the
            // run it identifies.
            ("seed", Json::str(self.seed.to_string())),
            ("zeta", Json::num(self.zeta)),
            ("n_queries", Json::num(self.n_queries as f64)),
            ("n_dropped", Json::num(self.n_dropped as f64)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("total_energy_j", Json::num(self.total_energy_j)),
            ("mean_latency_s", Json::num(self.mean_latency_s)),
            ("p50_latency_s", Json::num(self.p50_latency_s)),
            ("p95_latency_s", Json::num(self.p95_latency_s)),
            ("max_latency_s", Json::num(self.max_latency_s)),
            ("mean_queue_s", Json::num(self.mean_queue_s)),
            ("slo_s", Json::num(self.slo_s)),
            ("slo_attainment", Json::num(self.slo_attainment)),
            ("mean_utilization", Json::num(self.mean_utilization())),
            (
                "nodes",
                Json::arr(self.nodes.iter().map(|nd| {
                    Json::obj(vec![
                        ("model_id", Json::str(nd.model_id.clone())),
                        ("queries", Json::num(nd.queries as f64)),
                        ("batches", Json::num(nd.batches as f64)),
                        ("mean_batch_size", Json::num(nd.mean_batch_size())),
                        ("energy_j", Json::num(nd.energy_j)),
                        ("busy_s", Json::num(nd.busy_s)),
                        (
                            "utilization",
                            Json::num(if self.makespan_s > 0.0 {
                                nd.busy_s / self.makespan_s
                            } else {
                                0.0
                            }),
                        ),
                    ])
                })),
            ),
        ];
        if let Some((hits, misses)) = self.plan_decisions {
            fields.push((
                "plan_decisions",
                Json::obj(vec![
                    ("followed", Json::num(hits as f64)),
                    ("fallback", Json::num(misses as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u32, model: usize, arrive: f64, start: f64, complete: f64) -> QueryOutcome {
        QueryOutcome {
            id,
            model,
            t_arrive: arrive,
            t_start: start,
            t_complete: complete,
            energy_j: 2.0,
        }
    }

    fn metrics() -> SimMetrics {
        SimMetrics::from_outcomes(
            "greedy".into(),
            "poisson:10".into(),
            42,
            0.5,
            1.0,
            3,
            None,
            vec![
                NodeStats {
                    model_id: "small".into(),
                    queries: 2,
                    batches: 1,
                    energy_j: 4.0,
                    busy_s: 1.0,
                },
                NodeStats {
                    model_id: "big".into(),
                    queries: 1,
                    batches: 1,
                    energy_j: 2.0,
                    busy_s: 2.0,
                },
            ],
            vec![
                outcome(0, 0, 0.0, 0.5, 1.5),
                outcome(1, 0, 0.5, 0.5, 1.5),
                outcome(2, 1, 1.0, 1.0, 3.0),
            ],
        )
    }

    #[test]
    fn aggregates_are_correct() {
        let m = metrics();
        assert_eq!(m.n_queries, 3);
        assert_eq!(m.n_dropped, 3);
        assert_eq!(m.makespan_s, 3.0);
        assert_eq!(m.total_energy_j, 6.0);
        // latencies: 1.5, 1.0, 2.0
        assert!((m.mean_latency_s - 1.5).abs() < 1e-12);
        assert_eq!(m.max_latency_s, 2.0);
        assert_eq!(m.p50_latency_s, 1.5);
        // queue waits: 0.5, 0.0, 0.0
        assert!((m.mean_queue_s - 0.5 / 3.0).abs() < 1e-12);
        // SLO 1.0 s: only the 1.0-latency query attains it.
        assert!((m.slo_attainment - 1.0 / 3.0).abs() < 1e-12);
        // utilization: (1/3 + 2/3)/2
        assert!((m.mean_utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_is_deterministic_and_complete() {
        let a = metrics().to_json().to_string_pretty();
        let b = metrics().to_json().to_string_pretty();
        assert_eq!(a, b);
        // Seeds survive as exact decimal strings even above 2^53.
        assert!(a.contains("\"seed\": \"42\""), "{a}");
        let mut big = metrics();
        big.seed = (1u64 << 53) + 1;
        assert!(
            big.to_json()
                .to_string_pretty()
                .contains("\"seed\": \"9007199254740993\"")
        );
        for key in [
            "\"policy\"",
            "\"arrival\"",
            "\"total_energy_j\"",
            "\"slo_attainment\"",
            "\"nodes\"",
            "\"utilization\"",
        ] {
            assert!(a.contains(key), "missing {key} in {a}");
        }
        assert!(!a.contains("plan_decisions"));
        let mut m = metrics();
        m.plan_decisions = Some((2, 1));
        assert!(m.to_json().to_string_pretty().contains("plan_decisions"));
    }

    #[test]
    fn empty_run_has_no_nans() {
        let m = SimMetrics::from_outcomes(
            "greedy".into(),
            "poisson:1".into(),
            1,
            0.5,
            1.0,
            0,
            None,
            vec![],
            vec![],
        );
        let text = m.to_json().to_string_compact();
        assert!(!text.contains("null"), "{text}");
        assert_eq!(m.mean_latency_s, 0.0);
        assert_eq!(m.slo_attainment, 0.0);
    }
}
