//! Streaming accounting of a simulated serving run, and its deterministic
//! versioned JSON artifact.
//!
//! # O(1)-memory metrics (artifact version 2)
//!
//! Up to artifact version 1 the simulator kept one [`QueryOutcome`] per
//! query and computed exact quantiles by sorting at the end — O(|Q|)
//! memory and the single largest cost of a large run. Version 2 streams:
//! a `MetricsRecorder` folds each completion into O(1) accumulators
//! (counts, sums, maxima, SLO attainment) plus fixed-bin log-scale
//! [`LogHistogram`]s, from which p50/p95 are read back deterministically
//! to within one bin ratio (≈ 9% relative; see
//! [`crate::stats::histogram`]). Exact per-query outcomes — and the
//! exact sorted-vector quantiles they allow — are retained only on
//! request (`--per-query`, [`crate::sim::SimConfig::per_query`]), which
//! restores the O(|Q|) cost knowingly.
//!
//! # Token-level latency (artifact version 4)
//!
//! Version 4 records which engine produced the run (`engine`:
//! `lockstep` or `continuous`, [`crate::sim::EngineKind`]) and adds the
//! token-level latency metrics the continuous-batching engine exists to
//! improve:
//!
//! * **TTFT** (time to first token) — arrival to the completion of the
//!   query's first decode step, seconds. Under the lockstep engine the
//!   first-token instant is synthesized as-if-streamed (batch start +
//!   own prefill + one decode step), so the two engines are comparable.
//! * **TPOT** (time per output token) — `(t_complete − t_first_token) /
//!   max(1, n_tokens − 1)`: the steady-state inter-token gap; for a
//!   single-token generation the first token is the only token and TPOT
//!   degenerates to 0 elapsed over 1 token.
//!
//! Both stream through the same accumulator + log-histogram machinery as
//! latency and queue wait, with optional SLOs (`--ttft-slo-ms`,
//! `--tpot-slo-ms`) and attainment fractions. Energy is additionally
//! split by phase: per-node `prefill_j`/`decode_j` and run-level
//! `prefill_energy_j`/`decode_energy_j` (the calibrated prefill/decode
//! split of the fitted per-query predictions).
//!
//! # Replica clusters and failure injection (artifact version 5)
//!
//! Version 5 makes the node list a *replica* list: each hosted model may
//! be served by several replica nodes (`--replicas`), and a scripted
//! outage ([`crate::sim::FailureScript`], `--failures`) may kill, drain,
//! or join replicas mid-run. Every node row gains its `replica` index
//! within its model, its accumulated `downtime_s`, and the number of
//! queries `requeued` off it by kills; the run gains the `scenario`
//! label (`none`, or `chaos:N` for an N-event script) and the total
//! `n_requeued`. Unreplicated, failure-free runs emit `replica: 0`,
//! `downtime_s: 0`, `requeued: 0`, and `scenario: "none"` — the layout
//! change is the only delta against version 4.
//!
//! # Resilience accounting (artifact version 6)
//!
//! Version 6 adds request-level survival under stochastic outages
//! ([`crate::sim::ResilienceConfig`]): per-node `retries` (orphaned
//! copies re-dispatched after a kill), `hedges` (tail-hedge twins placed
//! on the node), and `breaker_trips` (circuit-breaker openings), with
//! run-level totals `n_retries`/`n_hedges`/`n_breaker_trips` that are
//! exact sums of the node rows. The run also gains `n_failed` (queries
//! whose retry budget was exhausted — never recorded as completions),
//! **`availability`** = `slo_attained / (n_queries + n_failed)` (an
//! SLO-availability: a query that misses its latency SLO or fails
//! outright counts unavailable; `1.0` on an empty run), and
//! **`goodput_qps`** = `slo_attained / makespan_s`. Runs without
//! resilience emit zeros for the new counters and the layout change is
//! the only delta against version 5.
//!
//! # Determinism
//!
//! The JSON layout is stable by construction: objects serialize through
//! [`Json`] (BTreeMap-backed, keys sorted), floats use Rust's shortest
//! round-trip formatting, and every value derives from virtual-time
//! arithmetic folded in event order — so equal `(workload, policy, seed,
//! config)` runs emit byte-identical artifacts. CI diffs two runs to
//! enforce this, for each engine.

use crate::control::{CarbonReport, CarbonWindow, ReplanStats};
use crate::stats::{quantile, LOG_HIST_BINS_PER_OCTAVE, LOG_HIST_LO_S, LogHistogram};
use crate::util::Json;

/// Version of the `ecoserve.sim-metrics` artifact this build writes.
/// Version 6 adds resilience accounting: retry/hedge/breaker counters
/// (per node and as run totals), failed-query counts, availability, and
/// goodput. Versions 1 (per-query exact quantiles, no histograms), 2
/// (pre-control), 3 (pre-phase-split), 4 (pre-cluster), and 5
/// (pre-resilience) are rejected on load with migration messages.
pub const SIM_METRICS_VERSION: u32 = 6;

/// Lifecycle of one simulated query (all times in virtual seconds from
/// simulation start). Only recorded when per-query retention is on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueryOutcome {
    /// workload query id, widened to u64 so future 64-bit trace id spaces
    /// need no artifact change
    pub id: u64,
    /// index of the serving model/node
    pub model: usize,
    pub t_arrive: f64,
    /// execution start: batch start (lockstep) or working-set admission
    /// (continuous)
    pub t_start: f64,
    /// completion of the first decode step (= first response token)
    pub t_first_token: f64,
    pub t_complete: f64,
    /// generated tokens (the workload's `t_out`)
    pub n_tokens: u32,
    /// predicted energy attributed to this query (Eq. 6 at its shape)
    pub energy_j: f64,
}

impl QueryOutcome {
    pub fn latency_s(&self) -> f64 {
        self.t_complete - self.t_arrive
    }

    pub fn queue_s(&self) -> f64 {
        self.t_start - self.t_arrive
    }

    /// Time to first token: arrival → first decode-step completion.
    pub fn ttft_s(&self) -> f64 {
        self.t_first_token - self.t_arrive
    }

    /// Time per output token after the first (steady-state decode gap).
    pub fn tpot_s(&self) -> f64 {
        (self.t_complete - self.t_first_token) / self.n_tokens.saturating_sub(1).max(1) as f64
    }
}

/// Accumulated counters for one simulated node (one replica of a hosted
/// model; unreplicated runs have exactly one node per model).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeStats {
    pub model_id: String,
    /// replica index within the model (0-based; joins append)
    pub replica: u32,
    pub queries: u64,
    /// executed batches (lockstep) or iterations (continuous)
    pub batches: u64,
    pub energy_j: f64,
    /// prefill's share of `energy_j` under the calibrated phase split
    /// (decode is the complement)
    pub prefill_j: f64,
    /// total virtual time the node's engine was executing
    pub busy_s: f64,
    /// total virtual time the replica was down (killed, draining, or
    /// warming up after a join)
    pub downtime_s: f64,
    /// queries requeued off this replica by scripted kills
    pub requeued: u64,
    /// orphaned copies this replica's kills sent into backoff-then-retry
    /// (resilience runs only; zero otherwise)
    pub retries: u64,
    /// tail-hedge twin copies placed on this replica
    pub hedges: u64,
    /// circuit-breaker openings on this replica
    pub breaker_trips: u64,
}

impl NodeStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.queries as f64 / self.batches as f64
    }
}

/// Streaming accumulator the event loop folds completions into: O(1)
/// memory unless per-query retention was requested.
#[derive(Debug, Clone)]
pub(crate) struct MetricsRecorder {
    slo_s: f64,
    ttft_slo_s: Option<f64>,
    tpot_slo_s: Option<f64>,
    n: u64,
    sum_latency_s: f64,
    sum_queue_s: f64,
    sum_ttft_s: f64,
    sum_tpot_s: f64,
    max_latency_s: f64,
    max_queue_s: f64,
    max_ttft_s: f64,
    max_tpot_s: f64,
    makespan_ns: u64,
    total_energy_j: f64,
    prefill_energy_j: f64,
    slo_attained: u64,
    ttft_attained: u64,
    tpot_attained: u64,
    latency_hist: LogHistogram,
    queue_hist: LogHistogram,
    ttft_hist: LogHistogram,
    tpot_hist: LogHistogram,
    outcomes: Option<Vec<QueryOutcome>>,
}

impl MetricsRecorder {
    pub(crate) fn new(
        slo_s: f64,
        ttft_slo_s: Option<f64>,
        tpot_slo_s: Option<f64>,
        per_query: bool,
    ) -> MetricsRecorder {
        MetricsRecorder {
            slo_s,
            ttft_slo_s,
            tpot_slo_s,
            n: 0,
            sum_latency_s: 0.0,
            sum_queue_s: 0.0,
            sum_ttft_s: 0.0,
            sum_tpot_s: 0.0,
            max_latency_s: 0.0,
            max_queue_s: 0.0,
            max_ttft_s: 0.0,
            max_tpot_s: 0.0,
            makespan_ns: 0,
            total_energy_j: 0.0,
            prefill_energy_j: 0.0,
            slo_attained: 0,
            ttft_attained: 0,
            tpot_attained: 0,
            latency_hist: LogHistogram::new(),
            queue_hist: LogHistogram::new(),
            ttft_hist: LogHistogram::new(),
            tpot_hist: LogHistogram::new(),
            outcomes: per_query.then(Vec::new),
        }
    }

    /// Completions recorded so far (the conservation check reads this).
    pub(crate) fn n(&self) -> u64 {
        self.n
    }

    /// Fold one completed query. Causality (`arrive ≤ start ≤ first
    /// token ≤ complete`) is the event loop's invariant; times are
    /// virtual nanoseconds, `n_tokens` the generated token count, and
    /// `prefill_j` the prefill share of `energy_j`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn record(
        &mut self,
        id: u64,
        model: usize,
        arrive_ns: u64,
        start_ns: u64,
        first_token_ns: u64,
        complete_ns: u64,
        n_tokens: u32,
        energy_j: f64,
        prefill_j: f64,
    ) {
        debug_assert!(
            arrive_ns <= start_ns && start_ns <= first_token_ns && first_token_ns <= complete_ns
        );
        let latency_s = (complete_ns - arrive_ns) as f64 / 1e9;
        let queue_s = (start_ns - arrive_ns) as f64 / 1e9;
        let ttft_s = (first_token_ns - arrive_ns) as f64 / 1e9;
        let tpot_s =
            (complete_ns - first_token_ns) as f64 / 1e9 / n_tokens.saturating_sub(1).max(1) as f64;
        self.n += 1;
        self.sum_latency_s += latency_s;
        self.sum_queue_s += queue_s;
        self.sum_ttft_s += ttft_s;
        self.sum_tpot_s += tpot_s;
        self.max_latency_s = self.max_latency_s.max(latency_s);
        self.max_queue_s = self.max_queue_s.max(queue_s);
        self.max_ttft_s = self.max_ttft_s.max(ttft_s);
        self.max_tpot_s = self.max_tpot_s.max(tpot_s);
        self.makespan_ns = self.makespan_ns.max(complete_ns);
        self.total_energy_j += energy_j;
        self.prefill_energy_j += prefill_j;
        if latency_s <= self.slo_s {
            self.slo_attained += 1;
        }
        if self.ttft_slo_s.is_some_and(|slo| ttft_s <= slo) {
            self.ttft_attained += 1;
        }
        if self.tpot_slo_s.is_some_and(|slo| tpot_s <= slo) {
            self.tpot_attained += 1;
        }
        self.latency_hist.record(latency_s);
        self.queue_hist.record(queue_s);
        self.ttft_hist.record(ttft_s);
        self.tpot_hist.record(tpot_s);
        if let Some(outcomes) = &mut self.outcomes {
            outcomes.push(QueryOutcome {
                id,
                model,
                t_arrive: arrive_ns as f64 / 1e9,
                t_start: start_ns as f64 / 1e9,
                t_first_token: first_token_ns as f64 / 1e9,
                t_complete: complete_ns as f64 / 1e9,
                n_tokens,
                energy_j,
            });
        }
    }

    /// Close the run into the metrics artifact.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn finish(
        self,
        policy: String,
        engine: String,
        scenario: String,
        arrival: String,
        seed: u64,
        zeta: f64,
        n_dropped: u64,
        n_requeued: u64,
        n_failed: u64,
        plan_decisions: Option<(u64, u64)>,
        nodes: Vec<NodeStats>,
    ) -> SimMetrics {
        let n = self.n;
        let mean = |sum: f64| if n == 0 { 0.0 } else { sum / n as f64 };
        let attainment = |attained: u64| {
            if n == 0 {
                0.0
            } else {
                attained as f64 / n as f64
            }
        };
        // SLO-availability: served within the SLO, over everything that
        // asked (failures included). An empty run is vacuously available.
        let availability = if n + n_failed == 0 {
            1.0
        } else {
            self.slo_attained as f64 / (n + n_failed) as f64
        };
        let makespan_s = self.makespan_ns as f64 / 1e9;
        let goodput_qps = if makespan_s > 0.0 {
            self.slo_attained as f64 / makespan_s
        } else {
            0.0
        };
        let n_retries = nodes.iter().map(|nd| nd.retries).sum();
        let n_hedges = nodes.iter().map(|nd| nd.hedges).sum();
        let n_breaker_trips = nodes.iter().map(|nd| nd.breaker_trips).sum();
        // Quantile estimates are bin upper edges, which sit strictly above
        // every sample in the bin — clamp to the exact streaming maximum
        // so the artifact never reports p95 > max (the estimate stays
        // within the same one-bin-ratio error band).
        SimMetrics {
            policy,
            engine,
            scenario,
            arrival,
            seed,
            zeta,
            n_queries: n,
            n_dropped,
            n_requeued,
            n_failed,
            n_retries,
            n_hedges,
            n_breaker_trips,
            availability,
            goodput_qps,
            makespan_s,
            total_energy_j: self.total_energy_j,
            prefill_energy_j: self.prefill_energy_j,
            decode_energy_j: self.total_energy_j - self.prefill_energy_j,
            mean_latency_s: mean(self.sum_latency_s),
            p50_latency_s: self.latency_hist.quantile(0.5).min(self.max_latency_s),
            p95_latency_s: self.latency_hist.quantile(0.95).min(self.max_latency_s),
            max_latency_s: self.max_latency_s,
            mean_queue_s: mean(self.sum_queue_s),
            p50_queue_s: self.queue_hist.quantile(0.5).min(self.max_queue_s),
            p95_queue_s: self.queue_hist.quantile(0.95).min(self.max_queue_s),
            max_queue_s: self.max_queue_s,
            mean_ttft_s: mean(self.sum_ttft_s),
            p50_ttft_s: self.ttft_hist.quantile(0.5).min(self.max_ttft_s),
            p95_ttft_s: self.ttft_hist.quantile(0.95).min(self.max_ttft_s),
            max_ttft_s: self.max_ttft_s,
            mean_tpot_s: mean(self.sum_tpot_s),
            p50_tpot_s: self.tpot_hist.quantile(0.5).min(self.max_tpot_s),
            p95_tpot_s: self.tpot_hist.quantile(0.95).min(self.max_tpot_s),
            max_tpot_s: self.max_tpot_s,
            slo_s: self.slo_s,
            slo_attainment: attainment(self.slo_attained),
            ttft_slo_s: self.ttft_slo_s,
            ttft_attainment: self.ttft_slo_s.map(|_| attainment(self.ttft_attained)),
            tpot_slo_s: self.tpot_slo_s,
            tpot_attainment: self.tpot_slo_s.map(|_| attainment(self.tpot_attained)),
            plan_decisions,
            nodes,
            latency_hist: self.latency_hist,
            queue_hist: self.queue_hist,
            ttft_hist: self.ttft_hist,
            tpot_hist: self.tpot_hist,
            outcomes: self.outcomes,
            // Control-plane blocks are attached by the simulator after the
            // streaming close-out (they come from the policy/meter, not
            // from completion folding).
            replan_stats: None,
            carbon: None,
            zeta_trajectory: None,
        }
    }
}

/// Aggregate metrics of one simulated run.
#[derive(Debug, Clone, PartialEq)]
pub struct SimMetrics {
    pub policy: String,
    /// execution model that produced the run (`lockstep`/`continuous`)
    pub engine: String,
    /// failure scenario the run was subjected to: `none`, or the
    /// script's label (`chaos:N` for an N-event [`FailureScript`]
    /// (crate::sim::FailureScript))
    pub scenario: String,
    pub arrival: String,
    pub seed: u64,
    pub zeta: f64,
    /// queries served (arrivals inside the duration window)
    pub n_queries: u64,
    /// arrivals dropped by the `--duration` cap
    pub n_dropped: u64,
    /// queries requeued by scripted replica kills (each served exactly
    /// once regardless — conservation is enforced by the simulator)
    pub n_requeued: u64,
    /// queries that exhausted their retry budget and were never served
    /// (resilience runs only; zero otherwise)
    pub n_failed: u64,
    /// retries scheduled across all replicas (= Σ node `retries`)
    pub n_retries: u64,
    /// hedge twins placed across all replicas (= Σ node `hedges`)
    pub n_hedges: u64,
    /// circuit-breaker openings across all replicas (= Σ node
    /// `breaker_trips`)
    pub n_breaker_trips: u64,
    /// SLO-availability: `slo_attained / (n_queries + n_failed)` — the
    /// fraction of asked-for queries served within the latency SLO
    /// (failed queries count against it; `1.0` on an empty run)
    pub availability: f64,
    /// within-SLO completions per virtual second of makespan
    pub goodput_qps: f64,
    /// last completion time (virtual seconds)
    pub makespan_s: f64,
    pub total_energy_j: f64,
    /// prefill's share of `total_energy_j` (calibrated phase split)
    pub prefill_energy_j: f64,
    /// decode's share of `total_energy_j` (complement of prefill)
    pub decode_energy_j: f64,
    pub mean_latency_s: f64,
    /// histogram-estimated (≤ one bin ratio from exact; see module docs),
    /// clamped to the exact maximum so p50/p95 never exceed it
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    /// exact streaming maximum
    pub max_latency_s: f64,
    pub mean_queue_s: f64,
    pub p50_queue_s: f64,
    pub p95_queue_s: f64,
    pub max_queue_s: f64,
    /// time to first token (arrival → first decode-step completion)
    pub mean_ttft_s: f64,
    pub p50_ttft_s: f64,
    pub p95_ttft_s: f64,
    pub max_ttft_s: f64,
    /// time per output token after the first
    pub mean_tpot_s: f64,
    pub p50_tpot_s: f64,
    pub p95_tpot_s: f64,
    pub max_tpot_s: f64,
    /// latency SLO the attainment fraction is measured against
    pub slo_s: f64,
    /// fraction of queries with latency ≤ `slo_s`
    pub slo_attainment: f64,
    /// TTFT SLO and attainment (`--ttft-slo-ms`; absent when unset)
    pub ttft_slo_s: Option<f64>,
    pub ttft_attainment: Option<f64>,
    /// TPOT SLO and attainment (`--tpot-slo-ms`; absent when unset)
    pub tpot_slo_s: Option<f64>,
    pub tpot_attainment: Option<f64>,
    /// (plan-followed, fallback) router decisions, plan policy only
    pub plan_decisions: Option<(u64, u64)>,
    pub nodes: Vec<NodeStats>,
    /// streaming latency distribution (serialized sparsely)
    pub latency_hist: LogHistogram,
    /// streaming queue-wait distribution
    pub queue_hist: LogHistogram,
    /// streaming time-to-first-token distribution
    pub ttft_hist: LogHistogram,
    /// streaming time-per-output-token distribution
    pub tpot_hist: LogHistogram,
    /// per-query lifecycle records; `Some` only when per-query retention
    /// (`--per-query`) was on — O(|Q|) memory, exact quantiles
    pub outcomes: Option<Vec<QueryOutcome>>,
    /// control-plane counters (replan policy only)
    pub replan_stats: Option<ReplanStats>,
    /// realized grams-CO₂ per carbon window (`--carbon` runs)
    pub carbon: Option<CarbonReport>,
    /// the governor's (t_s, ζ) steps (replan under carbon control)
    pub zeta_trajectory: Option<Vec<(f64, f64)>>,
}

fn hist_to_json(h: &LogHistogram) -> Json {
    // Flat (bin, count) pairs: half the nodes of nested pairs, still
    // self-describing next to the layout constants.
    let mut bins = Vec::new();
    for (bin, count) in h.nonzero() {
        bins.push(Json::num(bin as f64));
        bins.push(Json::num(count as f64));
    }
    Json::obj(vec![
        ("bins", Json::Arr(bins)),
        ("bins_per_octave", Json::num(LOG_HIST_BINS_PER_OCTAVE as f64)),
        ("lo_s", Json::num(LOG_HIST_LO_S)),
    ])
}

fn hist_from_json(v: &Json, what: &str) -> anyhow::Result<LogHistogram> {
    if v.as_obj().is_none() {
        anyhow::bail!("sim-metrics artifact: missing '{what}'");
    }
    let bpo = v.get("bins_per_octave").as_usize();
    let lo = v.get("lo_s").as_f64();
    if bpo != Some(LOG_HIST_BINS_PER_OCTAVE) || lo != Some(LOG_HIST_LO_S) {
        anyhow::bail!(
            "{what}: histogram layout {:?}/{:?} does not match this build \
             ({LOG_HIST_BINS_PER_OCTAVE} bins/octave from {LOG_HIST_LO_S} s); \
             regenerate the artifact",
            bpo,
            lo
        );
    }
    let flat = v
        .get("bins")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what}: missing 'bins' array"))?;
    if flat.len() % 2 != 0 {
        anyhow::bail!("{what}: 'bins' must hold (bin, count) pairs");
    }
    let mut pairs = Vec::with_capacity(flat.len() / 2);
    for chunk in flat.chunks_exact(2) {
        let bin = chunk[0]
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("{what}: non-integer bin index"))?;
        let count = chunk[1]
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("{what}: non-integer bin count"))?;
        pairs.push((bin, count));
    }
    LogHistogram::from_sparse(&pairs)
}

impl SimMetrics {
    /// Mean node utilization: busy time over makespan, averaged over
    /// nodes. Zero on an empty run.
    pub fn mean_utilization(&self) -> f64 {
        if self.makespan_s <= 0.0 || self.nodes.is_empty() {
            return 0.0;
        }
        self.nodes
            .iter()
            .map(|nd| nd.busy_s / self.makespan_s)
            .sum::<f64>()
            / self.nodes.len() as f64
    }

    /// The deterministic metrics artifact. Aggregates and histograms
    /// always; an `exact` block (sorted-vector quantiles) only when
    /// per-query outcomes were retained.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("format", Json::str("ecoserve.sim-metrics")),
            ("version", Json::num(SIM_METRICS_VERSION as f64)),
            ("policy", Json::str(self.policy.clone())),
            ("engine", Json::str(self.engine.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("arrival", Json::str(self.arrival.clone())),
            // As a decimal string: the f64-backed Json would round seeds
            // above 2^53 and the artifact could no longer reproduce the
            // run it identifies.
            ("seed", Json::str(self.seed.to_string())),
            ("zeta", Json::num(self.zeta)),
            ("n_queries", Json::num(self.n_queries as f64)),
            ("n_dropped", Json::num(self.n_dropped as f64)),
            ("n_requeued", Json::num(self.n_requeued as f64)),
            ("n_failed", Json::num(self.n_failed as f64)),
            ("n_retries", Json::num(self.n_retries as f64)),
            ("n_hedges", Json::num(self.n_hedges as f64)),
            ("n_breaker_trips", Json::num(self.n_breaker_trips as f64)),
            ("availability", Json::num(self.availability)),
            ("goodput_qps", Json::num(self.goodput_qps)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("total_energy_j", Json::num(self.total_energy_j)),
            ("prefill_energy_j", Json::num(self.prefill_energy_j)),
            ("decode_energy_j", Json::num(self.decode_energy_j)),
            ("mean_latency_s", Json::num(self.mean_latency_s)),
            ("p50_latency_s", Json::num(self.p50_latency_s)),
            ("p95_latency_s", Json::num(self.p95_latency_s)),
            ("max_latency_s", Json::num(self.max_latency_s)),
            ("mean_queue_s", Json::num(self.mean_queue_s)),
            ("p50_queue_s", Json::num(self.p50_queue_s)),
            ("p95_queue_s", Json::num(self.p95_queue_s)),
            ("max_queue_s", Json::num(self.max_queue_s)),
            ("mean_ttft_s", Json::num(self.mean_ttft_s)),
            ("p50_ttft_s", Json::num(self.p50_ttft_s)),
            ("p95_ttft_s", Json::num(self.p95_ttft_s)),
            ("max_ttft_s", Json::num(self.max_ttft_s)),
            ("mean_tpot_s", Json::num(self.mean_tpot_s)),
            ("p50_tpot_s", Json::num(self.p50_tpot_s)),
            ("p95_tpot_s", Json::num(self.p95_tpot_s)),
            ("max_tpot_s", Json::num(self.max_tpot_s)),
            ("slo_s", Json::num(self.slo_s)),
            ("slo_attainment", Json::num(self.slo_attainment)),
            ("mean_utilization", Json::num(self.mean_utilization())),
            ("latency_hist", hist_to_json(&self.latency_hist)),
            ("queue_hist", hist_to_json(&self.queue_hist)),
            ("ttft_hist", hist_to_json(&self.ttft_hist)),
            ("tpot_hist", hist_to_json(&self.tpot_hist)),
            (
                "nodes",
                Json::arr(self.nodes.iter().map(|nd| {
                    Json::obj(vec![
                        ("model_id", Json::str(nd.model_id.clone())),
                        ("replica", Json::num(nd.replica as f64)),
                        ("queries", Json::num(nd.queries as f64)),
                        ("batches", Json::num(nd.batches as f64)),
                        ("mean_batch_size", Json::num(nd.mean_batch_size())),
                        ("energy_j", Json::num(nd.energy_j)),
                        ("prefill_j", Json::num(nd.prefill_j)),
                        // Derived, not stored: the complement is emitted so
                        // dashboards need no arithmetic.
                        ("decode_j", Json::num(nd.energy_j - nd.prefill_j)),
                        ("busy_s", Json::num(nd.busy_s)),
                        ("downtime_s", Json::num(nd.downtime_s)),
                        ("requeued", Json::num(nd.requeued as f64)),
                        ("retries", Json::num(nd.retries as f64)),
                        ("hedges", Json::num(nd.hedges as f64)),
                        ("breaker_trips", Json::num(nd.breaker_trips as f64)),
                        (
                            "utilization",
                            Json::num(if self.makespan_s > 0.0 {
                                nd.busy_s / self.makespan_s
                            } else {
                                0.0
                            }),
                        ),
                    ])
                })),
            ),
        ];
        if let (Some(slo), Some(att)) = (self.ttft_slo_s, self.ttft_attainment) {
            fields.push(("ttft_slo_s", Json::num(slo)));
            fields.push(("ttft_attainment", Json::num(att)));
        }
        if let (Some(slo), Some(att)) = (self.tpot_slo_s, self.tpot_attainment) {
            fields.push(("tpot_slo_s", Json::num(slo)));
            fields.push(("tpot_attainment", Json::num(att)));
        }
        if let Some((hits, misses)) = self.plan_decisions {
            fields.push((
                "plan_decisions",
                Json::obj(vec![
                    ("followed", Json::num(hits as f64)),
                    ("fallback", Json::num(misses as f64)),
                ]),
            ));
        }
        if let Some(rs) = self.replan_stats {
            fields.push((
                "replan",
                Json::obj(vec![
                    ("replans", Json::num(rs.replans as f64)),
                    ("slo_replans", Json::num(rs.slo_replans as f64)),
                    ("planned_routed", Json::num(rs.planned_routed as f64)),
                    ("fallback_routed", Json::num(rs.fallback_routed as f64)),
                ]),
            ));
        }
        if let Some(carbon) = self.carbon.as_ref() {
            fields.push((
                "carbon",
                Json::obj(vec![
                    ("day_s", Json::num(carbon.day_s)),
                    ("total_g", Json::num(carbon.total_g)),
                    (
                        "windows",
                        Json::arr(carbon.windows.iter().map(|w| {
                            Json::obj(vec![
                                // Decimal string for the same reason as
                                // `seed`: window indices are u64 and the
                                // f64-backed Json would round past 2^53.
                                ("index", Json::str(w.index.to_string())),
                                ("start_s", Json::num(w.start_s)),
                                ("intensity_g_per_kwh", Json::num(w.intensity)),
                                ("energy_j", Json::num(w.energy_j)),
                                ("carbon_g", Json::num(w.carbon_g)),
                            ])
                        })),
                    ),
                ]),
            ));
        }
        if let Some(traj) = self.zeta_trajectory.as_ref() {
            // Flat (t_s, zeta) pairs, mirroring the histogram layout.
            let mut flat = Vec::with_capacity(traj.len() * 2);
            for &(t_s, z) in traj {
                flat.push(Json::num(t_s));
                flat.push(Json::num(z));
            }
            fields.push(("zeta_trajectory", Json::Arr(flat)));
        }
        if let Some(outcomes) = self.outcomes.as_ref().filter(|o| !o.is_empty()) {
            let lats: Vec<f64> = outcomes.iter().map(QueryOutcome::latency_s).collect();
            let queues: Vec<f64> = outcomes.iter().map(QueryOutcome::queue_s).collect();
            let ttfts: Vec<f64> = outcomes.iter().map(QueryOutcome::ttft_s).collect();
            let tpots: Vec<f64> = outcomes.iter().map(QueryOutcome::tpot_s).collect();
            fields.push((
                "exact",
                Json::obj(vec![
                    ("p50_latency_s", Json::num(quantile(&lats, 0.5))),
                    ("p95_latency_s", Json::num(quantile(&lats, 0.95))),
                    ("p50_queue_s", Json::num(quantile(&queues, 0.5))),
                    ("p95_queue_s", Json::num(quantile(&queues, 0.95))),
                    ("p50_ttft_s", Json::num(quantile(&ttfts, 0.5))),
                    ("p95_ttft_s", Json::num(quantile(&ttfts, 0.95))),
                    ("p50_tpot_s", Json::num(quantile(&tpots, 0.5))),
                    ("p95_tpot_s", Json::num(quantile(&tpots, 0.95))),
                ]),
            ));
        }
        Json::obj(fields)
    }

    /// Load an aggregates-only `SimMetrics` back from its artifact.
    /// Per-query outcomes (and the derived `exact` block) are not part of
    /// the artifact's reload surface. Version 1–3 artifacts are rejected
    /// with migration messages; the golden test pins both behaviors.
    pub fn from_json(v: &Json) -> anyhow::Result<SimMetrics> {
        match v.get("format").as_str() {
            Some("ecoserve.sim-metrics") => {}
            other => anyhow::bail!(
                "not a sim-metrics artifact (format {:?}, expected 'ecoserve.sim-metrics')",
                other
            ),
        }
        match v.get("version").as_u64() {
            Some(ver) if ver == SIM_METRICS_VERSION as u64 => {}
            Some(1) => anyhow::bail!(
                "sim-metrics artifact is version 1 (pre-streaming: exact quantiles, \
                 no histograms); this build reads version {SIM_METRICS_VERSION} — \
                 regenerate with `ecoserve simulate` (add --per-query if you need \
                 exact quantiles back)"
            ),
            Some(2) => anyhow::bail!(
                "sim-metrics artifact is version 2 (pre-control: no carbon, \
                 ζ-trajectory, or replan fields); this build reads version \
                 {SIM_METRICS_VERSION} — regenerate with `ecoserve simulate` \
                 (add --carbon for per-window carbon accounting)"
            ),
            Some(3) => anyhow::bail!(
                "sim-metrics artifact is version 3 (pre-phase-split: no engine \
                 label, TTFT/TPOT distributions, or per-phase energy); this build \
                 reads version {SIM_METRICS_VERSION} — regenerate with `ecoserve \
                 simulate` (--engine lockstep|continuous selects the engine)"
            ),
            Some(4) => anyhow::bail!(
                "sim-metrics artifact is version 4 (pre-cluster: no scenario \
                 label, requeue counts, or per-replica node accounting); this \
                 build reads version {SIM_METRICS_VERSION} — regenerate with \
                 `ecoserve simulate` (--replicas/--failures configure the \
                 replica fleet and outage script)"
            ),
            Some(5) => anyhow::bail!(
                "sim-metrics artifact is version 5 (pre-resilience: no \
                 retry/hedge/breaker accounting, failed-query counts, \
                 availability, or goodput); this build reads version \
                 {SIM_METRICS_VERSION} — regenerate with `ecoserve simulate` \
                 (--hazard/--retry-budget/--hedge-ms configure outage \
                 processes and request survival)"
            ),
            other => anyhow::bail!(
                "unsupported sim-metrics artifact version {:?} (this build reads \
                 version {SIM_METRICS_VERSION})",
                other
            ),
        }
        let num = |k: &str| -> anyhow::Result<f64> {
            v.get(k)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing/invalid '{k}'"))
        };
        let string = |k: &str| -> anyhow::Result<String> {
            v.get(k)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing/invalid '{k}'"))
        };
        let seed: u64 = string("seed")?
            .parse()
            .map_err(|_| anyhow::anyhow!("sim-metrics artifact: 'seed' is not a u64 string"))?;
        let nodes = v
            .get("nodes")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing 'nodes'"))?
            .iter()
            .map(|nd| -> anyhow::Result<NodeStats> {
                Ok(NodeStats {
                    model_id: nd
                        .get("model_id")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'model_id'"))?
                        .to_string(),
                    replica: nd
                        .get("replica")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'replica'"))?
                        as u32,
                    queries: nd
                        .get("queries")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'queries'"))?,
                    batches: nd
                        .get("batches")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'batches'"))?,
                    energy_j: nd
                        .get("energy_j")
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'energy_j'"))?,
                    prefill_j: nd
                        .get("prefill_j")
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'prefill_j'"))?,
                    busy_s: nd
                        .get("busy_s")
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'busy_s'"))?,
                    downtime_s: nd
                        .get("downtime_s")
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'downtime_s'"))?,
                    requeued: nd
                        .get("requeued")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'requeued'"))?,
                    retries: nd
                        .get("retries")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'retries'"))?,
                    hedges: nd
                        .get("hedges")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'hedges'"))?,
                    breaker_trips: nd
                        .get("breaker_trips")
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("node missing 'breaker_trips'"))?,
                })
            })
            .collect::<anyhow::Result<Vec<NodeStats>>>()?;
        let plan_decisions = match v.get("plan_decisions") {
            Json::Null => None,
            pd => Some((
                pd.get("followed")
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("plan_decisions missing 'followed'"))?,
                pd.get("fallback")
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("plan_decisions missing 'fallback'"))?,
            )),
        };
        let replan_stats = match v.get("replan") {
            Json::Null => None,
            rs => {
                let count = |k: &str| -> anyhow::Result<u64> {
                    rs.get(k)
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("replan missing '{k}'"))
                };
                Some(ReplanStats {
                    replans: count("replans")?,
                    slo_replans: count("slo_replans")?,
                    planned_routed: count("planned_routed")?,
                    fallback_routed: count("fallback_routed")?,
                })
            }
        };
        let carbon = match v.get("carbon") {
            Json::Null => None,
            c => {
                let cf = |j: &Json, k: &str| -> anyhow::Result<f64> {
                    j.get(k)
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("carbon missing '{k}'"))
                };
                let windows = c
                    .get("windows")
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("carbon missing 'windows'"))?
                    .iter()
                    .map(|w| -> anyhow::Result<CarbonWindow> {
                        Ok(CarbonWindow {
                            index: w
                                .get("index")
                                .as_str()
                                .ok_or_else(|| anyhow::anyhow!("carbon window missing 'index'"))?
                                .parse()
                                .map_err(|_| {
                                    anyhow::anyhow!(
                                        "carbon window 'index' is not a u64 string"
                                    )
                                })?,
                            start_s: cf(w, "start_s")?,
                            intensity: cf(w, "intensity_g_per_kwh")?,
                            energy_j: cf(w, "energy_j")?,
                            carbon_g: cf(w, "carbon_g")?,
                        })
                    })
                    .collect::<anyhow::Result<Vec<CarbonWindow>>>()?;
                Some(CarbonReport {
                    day_s: cf(c, "day_s")?,
                    total_g: cf(c, "total_g")?,
                    windows,
                })
            }
        };
        let zeta_trajectory = match v.get("zeta_trajectory") {
            Json::Null => None,
            zt => {
                let flat = zt
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("'zeta_trajectory' must be an array"))?;
                if flat.len() % 2 != 0 {
                    anyhow::bail!("'zeta_trajectory' must hold (t_s, zeta) pairs");
                }
                Some(
                    flat.chunks_exact(2)
                        .map(|c| -> anyhow::Result<(f64, f64)> {
                            let t_s = c[0].as_f64().ok_or_else(|| {
                                anyhow::anyhow!("zeta_trajectory: non-numeric time")
                            })?;
                            let z = c[1].as_f64().ok_or_else(|| {
                                anyhow::anyhow!("zeta_trajectory: non-numeric ζ")
                            })?;
                            Ok((t_s, z))
                        })
                        .collect::<anyhow::Result<Vec<(f64, f64)>>>()?,
                )
            }
        };
        // Optional token-level SLO pairs: absent keys stay `None`; a
        // present SLO requires its attainment.
        let opt_slo = |slo_key: &str, att_key: &str| -> anyhow::Result<(Option<f64>, Option<f64>)> {
            match v.get(slo_key) {
                Json::Null => Ok((None, None)),
                s => {
                    let slo = s.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("sim-metrics artifact: non-numeric '{slo_key}'")
                    })?;
                    let att = v.get(att_key).as_f64().ok_or_else(|| {
                        anyhow::anyhow!("sim-metrics artifact: '{slo_key}' without '{att_key}'")
                    })?;
                    Ok((Some(slo), Some(att)))
                }
            }
        };
        let (ttft_slo_s, ttft_attainment) = opt_slo("ttft_slo_s", "ttft_attainment")?;
        let (tpot_slo_s, tpot_attainment) = opt_slo("tpot_slo_s", "tpot_attainment")?;
        Ok(SimMetrics {
            policy: string("policy")?,
            engine: string("engine")?,
            scenario: string("scenario")?,
            arrival: string("arrival")?,
            seed,
            zeta: num("zeta")?,
            n_queries: v
                .get("n_queries")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing 'n_queries'"))?,
            n_dropped: v
                .get("n_dropped")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing 'n_dropped'"))?,
            n_requeued: v
                .get("n_requeued")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing 'n_requeued'"))?,
            n_failed: v
                .get("n_failed")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing 'n_failed'"))?,
            n_retries: v
                .get("n_retries")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing 'n_retries'"))?,
            n_hedges: v
                .get("n_hedges")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("sim-metrics artifact: missing 'n_hedges'"))?,
            n_breaker_trips: v.get("n_breaker_trips").as_u64().ok_or_else(|| {
                anyhow::anyhow!("sim-metrics artifact: missing 'n_breaker_trips'")
            })?,
            availability: num("availability")?,
            goodput_qps: num("goodput_qps")?,
            makespan_s: num("makespan_s")?,
            total_energy_j: num("total_energy_j")?,
            prefill_energy_j: num("prefill_energy_j")?,
            decode_energy_j: num("decode_energy_j")?,
            mean_latency_s: num("mean_latency_s")?,
            p50_latency_s: num("p50_latency_s")?,
            p95_latency_s: num("p95_latency_s")?,
            max_latency_s: num("max_latency_s")?,
            mean_queue_s: num("mean_queue_s")?,
            p50_queue_s: num("p50_queue_s")?,
            p95_queue_s: num("p95_queue_s")?,
            max_queue_s: num("max_queue_s")?,
            mean_ttft_s: num("mean_ttft_s")?,
            p50_ttft_s: num("p50_ttft_s")?,
            p95_ttft_s: num("p95_ttft_s")?,
            max_ttft_s: num("max_ttft_s")?,
            mean_tpot_s: num("mean_tpot_s")?,
            p50_tpot_s: num("p50_tpot_s")?,
            p95_tpot_s: num("p95_tpot_s")?,
            max_tpot_s: num("max_tpot_s")?,
            slo_s: num("slo_s")?,
            slo_attainment: num("slo_attainment")?,
            ttft_slo_s,
            ttft_attainment,
            tpot_slo_s,
            tpot_attainment,
            plan_decisions,
            nodes,
            latency_hist: hist_from_json(v.get("latency_hist"), "latency_hist")?,
            queue_hist: hist_from_json(v.get("queue_hist"), "queue_hist")?,
            ttft_hist: hist_from_json(v.get("ttft_hist"), "ttft_hist")?,
            tpot_hist: hist_from_json(v.get("tpot_hist"), "tpot_hist")?,
            outcomes: None,
            replan_stats,
            carbon,
            zeta_trajectory,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[allow(clippy::too_many_arguments)]
    fn record_outcome(
        r: &mut MetricsRecorder,
        id: u64,
        model: usize,
        arrive_s: f64,
        start_s: f64,
        first_token_s: f64,
        complete_s: f64,
        n_tokens: u32,
    ) {
        let ns = |s: f64| (s * 1e9).round() as u64;
        r.record(
            id,
            model,
            ns(arrive_s),
            ns(start_s),
            ns(first_token_s),
            ns(complete_s),
            n_tokens,
            2.0,
            0.8,
        );
    }

    fn metrics(per_query: bool) -> SimMetrics {
        let mut r = MetricsRecorder::new(1.0, Some(0.45), None, per_query);
        // TTFTs 0.7, 0.4, 0.5; TPOTs 0.2, 0.3, 1.5.
        record_outcome(&mut r, 0, 0, 0.0, 0.5, 0.7, 1.5, 5);
        record_outcome(&mut r, 1, 0, 0.5, 0.5, 0.9, 1.5, 3);
        record_outcome(&mut r, 2, 1, 1.0, 1.0, 1.5, 3.0, 1);
        r.finish(
            "greedy".into(),
            "lockstep".into(),
            "none".into(),
            "poisson:10".into(),
            42,
            0.5,
            3,
            0,
            0,
            None,
            vec![
                NodeStats {
                    model_id: "small".into(),
                    queries: 2,
                    batches: 1,
                    energy_j: 4.0,
                    prefill_j: 1.6,
                    busy_s: 1.0,
                    ..NodeStats::default()
                },
                NodeStats {
                    model_id: "big".into(),
                    queries: 1,
                    batches: 1,
                    energy_j: 2.0,
                    prefill_j: 0.8,
                    busy_s: 2.0,
                    ..NodeStats::default()
                },
            ],
        )
    }

    #[test]
    fn aggregates_are_correct() {
        let m = metrics(false);
        assert_eq!(m.engine, "lockstep");
        assert_eq!(m.n_queries, 3);
        assert_eq!(m.n_dropped, 3);
        assert_eq!(m.makespan_s, 3.0);
        assert_eq!(m.total_energy_j, 6.0);
        // Each query recorded 0.8 J of prefill against 2.0 J total.
        assert!((m.prefill_energy_j - 2.4).abs() < 1e-12);
        assert!((m.decode_energy_j - 3.6).abs() < 1e-12);
        // latencies: 1.5, 1.0, 2.0
        assert!((m.mean_latency_s - 1.5).abs() < 1e-12);
        assert_eq!(m.max_latency_s, 2.0);
        // Histogram p50: within one bin ratio of the exact 1.5.
        let ratio = 2f64.powf(1.0 / LOG_HIST_BINS_PER_OCTAVE as f64);
        assert!(m.p50_latency_s >= 1.5 && m.p50_latency_s <= 1.5 * ratio * (1.0 + 1e-12));
        // queue waits: 0.5, 0.0, 0.0
        assert!((m.mean_queue_s - 0.5 / 3.0).abs() < 1e-12);
        assert_eq!(m.p50_queue_s, 0.0); // median queue wait is exactly zero
        assert_eq!(m.max_queue_s, 0.5);
        // TTFTs 0.7, 0.4, 0.5: mean 8/15, max 0.7; TTFT SLO 0.45 admits
        // only the 0.4 → attainment 1/3.
        assert!((m.mean_ttft_s - (0.7 + 0.4 + 0.5) / 3.0).abs() < 1e-12);
        assert_eq!(m.max_ttft_s, 0.7);
        assert!((m.ttft_attainment.unwrap() - 1.0 / 3.0).abs() < 1e-12);
        // TPOTs: (1.5−0.7)/4, (1.5−0.9)/2, and the single-token query's
        // (3.0−1.5)/1 — the max.
        assert!((m.mean_tpot_s - (0.2 + 0.3 + 1.5) / 3.0).abs() < 1e-12);
        assert_eq!(m.max_tpot_s, 1.5);
        // No TPOT SLO requested → no attainment reported.
        assert!(m.tpot_slo_s.is_none() && m.tpot_attainment.is_none());
        // SLO 1.0 s: only the 1.0-latency query attains it.
        assert!((m.slo_attainment - 1.0 / 3.0).abs() < 1e-12);
        // No failures: availability coincides with attainment, and
        // goodput is the one within-SLO completion over the makespan.
        assert_eq!(m.n_failed, 0);
        assert!((m.availability - 1.0 / 3.0).abs() < 1e-12);
        assert!((m.goodput_qps - 1.0 / 3.0).abs() < 1e-12);
        // utilization: (1/3 + 2/3)/2
        assert!((m.mean_utilization() - 0.5).abs() < 1e-12);
        // Streaming mode retains nothing per query.
        assert!(m.outcomes.is_none());
        assert_eq!(m.latency_hist.n(), 3);
        assert_eq!(m.ttft_hist.n(), 3);
        assert_eq!(m.tpot_hist.n(), 3);
    }

    #[test]
    fn per_query_mode_retains_outcomes_and_exact_quantiles() {
        let m = metrics(true);
        let outcomes = m.outcomes.as_ref().unwrap();
        assert_eq!(outcomes.len(), 3);
        assert_eq!(outcomes[2].id, 2);
        assert!((outcomes[0].latency_s() - 1.5).abs() < 1e-12);
        assert!((outcomes[0].ttft_s() - 0.7).abs() < 1e-12);
        assert!((outcomes[0].tpot_s() - 0.2).abs() < 1e-12);
        // Single-token generation: TPOT divisor floors at 1.
        assert!((outcomes[2].tpot_s() - 1.5).abs() < 1e-12);
        let json = m.to_json().to_string_pretty();
        assert!(json.contains("\"exact\""), "{json}");
        assert!(json.contains("\"p95_latency_s\""));
        assert!(json.contains("\"p95_ttft_s\""));
        assert!(json.contains("\"p95_tpot_s\""));
        // Aggregates are identical with and without retention.
        let lean = metrics(false);
        assert_eq!(lean.p50_latency_s, m.p50_latency_s);
        assert_eq!(lean.total_energy_j, m.total_energy_j);
        assert!(!lean.to_json().to_string_pretty().contains("\"exact\""));
    }

    #[test]
    fn json_is_deterministic_and_complete() {
        let a = metrics(false).to_json().to_string_pretty();
        let b = metrics(false).to_json().to_string_pretty();
        assert_eq!(a, b);
        // Seeds survive as exact decimal strings even above 2^53.
        assert!(a.contains("\"seed\": \"42\""), "{a}");
        let mut big = metrics(false);
        big.seed = (1u64 << 53) + 1;
        assert!(
            big.to_json()
                .to_string_pretty()
                .contains("\"seed\": \"9007199254740993\"")
        );
        for key in [
            "\"policy\"",
            "\"engine\": \"lockstep\"",
            "\"scenario\": \"none\"",
            "\"arrival\"",
            "\"version\": 6",
            "\"n_requeued\": 0",
            "\"n_failed\": 0",
            "\"n_retries\": 0",
            "\"n_hedges\": 0",
            "\"n_breaker_trips\": 0",
            "\"availability\"",
            "\"goodput_qps\"",
            "\"replica\": 0",
            "\"downtime_s\": 0",
            "\"requeued\": 0",
            "\"retries\": 0",
            "\"hedges\": 0",
            "\"breaker_trips\": 0",
            "\"total_energy_j\"",
            "\"prefill_energy_j\"",
            "\"decode_energy_j\"",
            "\"slo_attainment\"",
            "\"ttft_slo_s\"",
            "\"ttft_attainment\"",
            "\"mean_ttft_s\"",
            "\"p95_tpot_s\"",
            "\"latency_hist\"",
            "\"queue_hist\"",
            "\"ttft_hist\"",
            "\"tpot_hist\"",
            "\"bins_per_octave\"",
            "\"p95_queue_s\"",
            "\"nodes\"",
            "\"prefill_j\"",
            "\"decode_j\"",
            "\"utilization\"",
        ] {
            assert!(a.contains(key), "missing {key} in {a}");
        }
        // Absent SLOs emit no keys (no nulls in lean artifacts).
        assert!(!a.contains("tpot_slo_s"));
        assert!(!a.contains("tpot_attainment"));
        assert!(!a.contains("plan_decisions"));
        let mut m = metrics(false);
        m.plan_decisions = Some((2, 1));
        assert!(m.to_json().to_string_pretty().contains("plan_decisions"));
    }

    #[test]
    fn artifact_roundtrips_through_from_json() {
        let mut m = metrics(false);
        m.plan_decisions = Some((2, 1));
        let json = m.to_json();
        let back = SimMetrics::from_json(&json).unwrap();
        assert_eq!(back, m);
        // And byte-for-byte through a reserialize.
        assert_eq!(
            back.to_json().to_string_pretty(),
            json.to_string_pretty()
        );
    }

    #[test]
    fn control_blocks_roundtrip_with_decimal_window_indices() {
        let mut m = metrics(false);
        m.replan_stats = Some(ReplanStats {
            replans: 4,
            slo_replans: 1,
            planned_routed: 90,
            fallback_routed: 10,
        });
        m.carbon = Some(CarbonReport {
            day_s: 86400.0,
            total_g: 3.25,
            windows: vec![
                CarbonWindow {
                    index: 0,
                    start_s: 0.0,
                    intensity: 210.0,
                    energy_j: 18000.0,
                    carbon_g: 1.05,
                },
                CarbonWindow {
                    // Above 2^53: only the decimal-string encoding keeps
                    // this exact through the f64-backed Json.
                    index: (1u64 << 53) + 1,
                    start_s: 3600.0,
                    intensity: 200.0,
                    energy_j: 39600.0,
                    carbon_g: 2.2,
                },
            ],
        });
        m.zeta_trajectory = Some(vec![(0.0, 0.24), (3600.0, 0.31)]);
        let json = m.to_json();
        let text = json.to_string_pretty();
        assert!(text.contains("\"replan\""), "{text}");
        assert!(text.contains("\"carbon\""), "{text}");
        assert!(text.contains("\"index\": \"9007199254740993\""), "{text}");
        assert!(text.contains("\"zeta_trajectory\""), "{text}");
        let back = SimMetrics::from_json(&json).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.to_json().to_string_pretty(), text);
        // Absent blocks stay absent (no nulls in lean artifacts).
        let lean = metrics(false).to_json().to_string_pretty();
        for key in ["\"replan\"", "\"carbon\"", "\"zeta_trajectory\""] {
            assert!(!lean.contains(key), "unexpected {key} in {lean}");
        }
    }

    #[test]
    fn from_json_rejects_old_and_foreign_artifacts() {
        let v1 = Json::parse(
            r#"{"format": "ecoserve.sim-metrics", "version": 1, "policy": "plan"}"#,
        )
        .unwrap();
        let err = SimMetrics::from_json(&v1).unwrap_err().to_string();
        assert!(err.contains("version 1"), "{err}");
        assert!(err.contains("regenerate"), "{err}");

        let v2 = Json::parse(
            r#"{"format": "ecoserve.sim-metrics", "version": 2, "policy": "plan"}"#,
        )
        .unwrap();
        let err = SimMetrics::from_json(&v2).unwrap_err().to_string();
        assert!(err.contains("version 2"), "{err}");
        assert!(err.contains("pre-control"), "{err}");
        assert!(err.contains("regenerate"), "{err}");

        let v3 = Json::parse(
            r#"{"format": "ecoserve.sim-metrics", "version": 3, "policy": "plan"}"#,
        )
        .unwrap();
        let err = SimMetrics::from_json(&v3).unwrap_err().to_string();
        assert!(err.contains("version 3"), "{err}");
        assert!(err.contains("pre-phase-split"), "{err}");
        assert!(err.contains("--engine"), "{err}");

        let v4 = Json::parse(
            r#"{"format": "ecoserve.sim-metrics", "version": 4, "policy": "plan"}"#,
        )
        .unwrap();
        let err = SimMetrics::from_json(&v4).unwrap_err().to_string();
        assert!(err.contains("version 4"), "{err}");
        assert!(err.contains("pre-cluster"), "{err}");
        assert!(err.contains("regenerate"), "{err}");
        assert!(err.contains("--replicas"), "{err}");

        let v5 = Json::parse(
            r#"{"format": "ecoserve.sim-metrics", "version": 5, "policy": "plan"}"#,
        )
        .unwrap();
        let err = SimMetrics::from_json(&v5).unwrap_err().to_string();
        assert!(err.contains("version 5"), "{err}");
        assert!(err.contains("pre-resilience"), "{err}");
        assert!(err.contains("regenerate"), "{err}");
        assert!(err.contains("--hazard"), "{err}");

        let foreign = Json::parse(r#"{"format": "ecoserve.plan", "version": 2}"#).unwrap();
        let err = SimMetrics::from_json(&foreign).unwrap_err().to_string();
        assert!(err.contains("ecoserve.sim-metrics"), "{err}");

        let future = Json::parse(
            r#"{"format": "ecoserve.sim-metrics", "version": 99}"#,
        )
        .unwrap();
        let err = SimMetrics::from_json(&future).unwrap_err().to_string();
        assert!(err.contains("99"), "{err}");
    }

    #[test]
    fn empty_run_has_no_nans() {
        let m = MetricsRecorder::new(1.0, None, None, false).finish(
            "greedy".into(),
            "continuous".into(),
            "none".into(),
            "poisson:1".into(),
            1,
            0.5,
            0,
            0,
            0,
            None,
            vec![],
        );
        let text = m.to_json().to_string_compact();
        assert!(!text.contains("null"), "{text}");
        assert_eq!(m.mean_latency_s, 0.0);
        assert_eq!(m.p95_latency_s, 0.0);
        assert_eq!(m.mean_ttft_s, 0.0);
        assert_eq!(m.p95_tpot_s, 0.0);
        assert_eq!(m.slo_attainment, 0.0);
        // Vacuous availability: nothing asked, nothing denied.
        assert_eq!(m.availability, 1.0);
        assert_eq!(m.goodput_qps, 0.0);
        assert!(m.ttft_attainment.is_none());
    }
}
