//! `ecoserve::sim` — a deterministic discrete-event serving simulator:
//! what does an offline [`Plan`](crate::plan::Plan) actually cost when
//! queries arrive *over time*?
//!
//! The paper evaluates energy-optimal schedules offline, on a workload
//! known in full. Deployed serving is different: queries arrive under a
//! stochastic process, batchers hold them back, engines serialize them,
//! and queueing decides whether the plan's predicted energy/latency
//! survives burstiness. This module closes that loop without hardware —
//! at tens-of-millions-of-queries scale:
//!
//! * [`ArrivalProcess`] — Poisson, Gamma-burst, or trace-replayed
//!   (`t_arrive` in the workload JSONL) arrival timestamps, all seeded
//!   through [`util::Rng`](crate::util::Rng);
//! * [`SimPolicy`] — the routing decision per arriving query:
//!   plan-following (the production
//!   [`Router::with_plan`](crate::coordinator::Router::with_plan)
//!   handoff), closed-loop replanning
//!   ([`ReplanPolicy`](crate::control::ReplanPolicy), optionally under
//!   carbon-aware ζ control), ζ-cost greedy (shape-memoized),
//!   round-robin, or seeded random;
//! * [`FailureScript`] — seeded replica-lifecycle injection (abrupt
//!   kill with in-flight requeue, drain-then-leave, autoscale-join with
//!   warm-up), replayed deterministically on the virtual clock across
//!   per-model replica fleets (`--replicas`, `--failures`);
//! * [`Hazard`] — stochastic failure-*process* generators (`--hazard`):
//!   Poisson MTBF/MTTR, Weibull wear-out, correlated group failures,
//!   and spot-price preemption, all lowered into seeded
//!   [`FailureScript`]s so outage *ensembles* reuse the same machinery;
//! * [`Simulator`] — the zero-allocation event loop (arrive → route →
//!   batch → execute → complete) on a virtual integer-nanosecond clock,
//!   with two selectable engines ([`EngineKind`], `--engine`): batch-
//!   serial **lockstep** (the paper's measurement protocol) and
//!   iteration-level **continuous batching** with a calibrated
//!   prefill/decode phase split. Both share the hot-path machinery:
//!   `Copy` heap events, per-node index FIFOs instead of per-batch
//!   vectors, arrivals streamed from one sorted array, and Eq. 6–7
//!   service/energy predictions (plus the phase split) precomputed once
//!   per (shape, model) via the scheduler's shape bucketing;
//! * [`SimMetrics`] — streaming aggregates in O(1) memory: counts, sums,
//!   maxima, SLO attainment, and fixed-bin log-scale histograms
//!   ([`crate::stats::LogHistogram`]) for latency, queue wait, TTFT, and
//!   TPOT p50/p95, plus per-phase (prefill/decode) energy; per-query
//!   [`QueryOutcome`] lifecycles (and exact quantiles) only behind
//!   `--per-query`. Serialized as a byte-stable versioned JSON artifact;
//! * [`compare()`] / [`compare_replicated()`] — the same seeded trace
//!   replayed under several policies in one invocation (`ecoserve
//!   simulate --policy compare`), optionally replicated over `--seeds N`
//!   arrival draws with cross-seed confidence intervals; the policy×seed
//!   grid fans out across scoped threads and merges in fixed order.
//!
//! # Determinism contract
//!
//! A run is a pure function of `(model sets, workload, arrival times,
//! policy, seed, SimConfig)`. Virtual time is integer nanoseconds,
//! arrivals win event-time ties (then creation order), all randomness
//! flows from the seed, and the JSON artifact serializes through sorted
//! maps with shortest round-trip float formatting — so repeated runs are
//! byte-identical (property-tested in `tests/sim.rs`, diffed in CI's
//! `sim-smoke`, including the parallel `--seeds` comparison and the
//! replan+carbon control loop). The event loop's controller hook is the
//! seam online features plug into: [`crate::control`] already drives
//! closed-loop replanning and carbon-aware ζ scheduling through it, and
//! it remains open for preemption/DVFS — fast enough to drive them at
//! cluster scale (`benches/sim_scaling.rs`).

pub mod arrival;
pub mod compare;
pub mod failure;
pub mod hazard;
pub mod metrics;
pub mod policy;
pub mod simulator;

pub use arrival::{trace_times, ARRIVAL_SEED_SALT, ArrivalProcess};
pub use compare::{
    compare, compare_replicated, comparison_to_json, replicated_to_json, Arrivals, CompareSpec,
};
pub use failure::{FailureEvent, FailureKind, FailureScript};
pub use hazard::{load_price_trace, Hazard, HazardKind, PricePoint, HAZARD_SEED_SALT};
pub use metrics::{NodeStats, QueryOutcome, SIM_METRICS_VERSION, SimMetrics};
pub use policy::{PolicyKind, SimPolicy};
pub use simulator::{EngineKind, ResilienceConfig, SimConfig, Simulator};
