//! `ecoserve::sim` — a deterministic discrete-event serving simulator:
//! what does an offline [`Plan`](crate::plan::Plan) actually cost when
//! queries arrive *over time*?
//!
//! The paper evaluates energy-optimal schedules offline, on a workload
//! known in full. Deployed serving is different: queries arrive under a
//! stochastic process, batchers hold them back, engines serialize them,
//! and queueing decides whether the plan's predicted energy/latency
//! survives burstiness. This module closes that loop without hardware:
//!
//! * [`ArrivalProcess`] — Poisson, Gamma-burst, or trace-replayed
//!   (`t_arrive` in the workload JSONL) arrival timestamps, all seeded
//!   through [`util::Rng`](crate::util::Rng);
//! * [`SimPolicy`] — the routing decision per arriving query:
//!   plan-following (the production
//!   [`Router::with_plan`](crate::coordinator::Router::with_plan)
//!   handoff), ζ-cost greedy, round-robin, or seeded random;
//! * [`Simulator`] — the event loop (arrive → route → batch → execute →
//!   complete) on a virtual integer-nanosecond clock, with one
//!   [`Batcher`](crate::coordinator::Batcher)-fronted serial engine per
//!   hosted model, service times and energies taken from the fitted
//!   workload models (Eqs. 6–7);
//! * [`SimMetrics`] — per-query lifecycles and per-node accounting
//!   (energy J, latency, queue wait, SLO attainment, utilization),
//!   serialized as a byte-stable JSON artifact;
//! * [`compare()`] — the same seeded trace replayed under several
//!   policies in one invocation (`ecoserve simulate --policy compare`).
//!
//! # Determinism contract
//!
//! A run is a pure function of `(model sets, workload, arrival times,
//! policy, seed, SimConfig)`. Virtual time is integer nanoseconds, event
//! ties break on creation order, all randomness flows from the seed, and
//! the JSON artifact serializes through sorted maps with shortest
//! round-trip float formatting — so repeated runs are byte-identical
//! (property-tested in `tests/sim.rs`, diffed in CI's `sim-smoke`).
//! This event loop is the seam future online features (preemption, DVFS,
//! carbon-aware ζ control) plug into.

pub mod arrival;
pub mod compare;
pub mod metrics;
pub mod policy;
pub mod simulator;

pub use arrival::{trace_times, ArrivalProcess};
pub use compare::{compare, comparison_to_json, CompareSpec};
pub use metrics::{NodeStats, QueryOutcome, SimMetrics};
pub use policy::{PolicyKind, SimPolicy};
pub use simulator::{SimConfig, Simulator};
