//! Stochastic failure-process generators: seeded hazards lowered into
//! the deterministic [`FailureScript`] event stream.
//!
//! PR 9's scripts are hand-authored; real outage studies need *ensembles*
//! — many independent draws of the same failure process over the same
//! workload. A [`Hazard`] is a parametric process (`--hazard SPEC`) that,
//! given the initial replica fleet, a horizon, and a seed, generates one
//! concrete kill/join script. Because the output is an ordinary
//! `FailureScript`, everything downstream — both engines, the requeue and
//! parked-work machinery, byte-stable artifacts — is reused unchanged,
//! and the same `(hazard, fleet, horizon, seed)` tuple always generates
//! the same script.
//!
//! Four processes:
//!
//! * **`mtbf:MTBF:MTTR`** — per-replica alternating renewal with
//!   exponential uptimes (mean MTBF seconds) and exponential repair
//!   times (mean MTTR): the classic constant-hazard Poisson breakage
//!   model.
//! * **`weibull:SHAPE:SCALE:MTTR`** — Weibull(k, λ) uptimes. `SHAPE > 1`
//!   models wear-out (a replica that has been up longer is more likely
//!   to fail), `SHAPE < 1` infant mortality; repairs stay exponential.
//! * **`group:MTBF:MTTR:SIZE`** — correlated failures: the flat
//!   model-major replica list is partitioned into consecutive groups of
//!   `SIZE` (racks sharing a PSU/ToR), and one exponential process per
//!   group kills and revives every member at the same instant.
//! * **`spot:LO:HI`** — spot preemption: each replica draws a bid
//!   uniformly in `[LO, HI)` and replays a JSONL price trace
//!   ([`Hazard::with_price_trace`], `--spot-trace`); the replica is
//!   reclaimed when the price first exceeds its bid and re-joins when it
//!   falls back below.
//!
//! Every kill is paired with the join that repairs it (repairs may land
//! past the horizon — the simulator keeps draining scripted events after
//! the last arrival), so generated scripts can never strand parked work:
//! even a draw that downs a model's whole fleet eventually revives it.

use crate::sim::failure::{FailureEvent, FailureKind, FailureScript};
use crate::util::{Json, Rng};

/// Seed salt for hazard generation: ensemble member `i` draws its outage
/// script from `Rng::new(hazard_seed.wrapping_add(i) ^ HAZARD_SEED_SALT)`,
/// so outage randomness never collides with arrival randomness
/// ([`ARRIVAL_SEED_SALT`](crate::sim::ARRIVAL_SEED_SALT)) or policy
/// randomness derived from the same seed.
pub const HAZARD_SEED_SALT: u64 = 0xFA11_0E7E;

/// One point of a spot-market price trace (`--spot-trace FILE`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PricePoint {
    /// virtual time of the quote, seconds
    pub t_s: f64,
    /// market price in arbitrary units (compared against replica bids)
    pub price: f64,
}

/// The parametric failure process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HazardKind {
    /// exponential uptimes (mean `mtbf_s`) and repairs (mean `mttr_s`)
    /// per replica. CLI: `mtbf:MTBF:MTTR`.
    Mtbf { mtbf_s: f64, mttr_s: f64 },
    /// Weibull(shape, scale) uptimes, exponential repairs.
    /// CLI: `weibull:SHAPE:SCALE:MTTR`.
    Weibull {
        shape: f64,
        scale_s: f64,
        mttr_s: f64,
    },
    /// one exponential process per consecutive group of `group` replicas
    /// (model-major); a draw downs the whole group at once.
    /// CLI: `group:MTBF:MTTR:SIZE`.
    Group {
        mtbf_s: f64,
        mttr_s: f64,
        group: usize,
    },
    /// spot preemption against a price trace; per-replica bids drawn
    /// uniformly in `[bid_lo, bid_hi)`. CLI: `spot:LO:HI`.
    Spot { bid_lo: f64, bid_hi: f64 },
}

/// A seeded failure-process generator (`--hazard SPEC`).
#[derive(Debug, Clone, PartialEq)]
pub struct Hazard {
    pub kind: HazardKind,
    /// warm-up attached to every generated join, seconds
    /// (`--hazard-warmup`)
    pub warmup_s: f64,
    /// price trace for [`HazardKind::Spot`] (`--spot-trace`)
    pub price_trace: Vec<PricePoint>,
}

impl Hazard {
    /// Parse the CLI spelling
    /// (`mtbf:MTBF:MTTR | weibull:SHAPE:SCALE:MTTR | group:MTBF:MTTR:SIZE
    /// | spot:LO:HI`).
    pub fn parse(s: &str) -> anyhow::Result<Hazard> {
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or_default();
        let nums: Vec<&str> = parts.collect();
        let num = |i: usize, what: &str| -> anyhow::Result<f64> {
            let raw = nums
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("hazard '{s}': missing {what}"))?;
            let x: f64 = raw
                .parse()
                .map_err(|_| anyhow::anyhow!("hazard '{s}': {what} must be a number"))?;
            if !x.is_finite() || x <= 0.0 {
                anyhow::bail!("hazard '{s}': {what} must be positive, got {raw}");
            }
            Ok(x)
        };
        let kind = match head {
            "mtbf" => {
                if nums.len() != 2 {
                    anyhow::bail!("hazard '{s}': expected mtbf:MTBF:MTTR (seconds)");
                }
                HazardKind::Mtbf {
                    mtbf_s: num(0, "MTBF")?,
                    mttr_s: num(1, "MTTR")?,
                }
            }
            "weibull" => {
                if nums.len() != 3 {
                    anyhow::bail!("hazard '{s}': expected weibull:SHAPE:SCALE:MTTR");
                }
                HazardKind::Weibull {
                    shape: num(0, "SHAPE")?,
                    scale_s: num(1, "SCALE")?,
                    mttr_s: num(2, "MTTR")?,
                }
            }
            "group" => {
                if nums.len() != 3 {
                    anyhow::bail!("hazard '{s}': expected group:MTBF:MTTR:SIZE");
                }
                let size = num(2, "SIZE")?;
                if size.fract() != 0.0 {
                    anyhow::bail!("hazard '{s}': SIZE must be an integer, got {size}");
                }
                HazardKind::Group {
                    mtbf_s: num(0, "MTBF")?,
                    mttr_s: num(1, "MTTR")?,
                    group: size as usize,
                }
            }
            "spot" => {
                if nums.len() != 2 {
                    anyhow::bail!("hazard '{s}': expected spot:LO:HI (bid range)");
                }
                let lo = num(0, "LO")?;
                let hi = num(1, "HI")?;
                if lo >= hi {
                    anyhow::bail!("hazard '{s}': bid range needs LO < HI, got [{lo}, {hi})");
                }
                HazardKind::Spot {
                    bid_lo: lo,
                    bid_hi: hi,
                }
            }
            other => anyhow::bail!(
                "unknown hazard '{other}' (expected mtbf:MTBF:MTTR|\
                 weibull:SHAPE:SCALE:MTTR|group:MTBF:MTTR:SIZE|spot:LO:HI)"
            ),
        };
        Ok(Hazard {
            kind,
            warmup_s: 0.0,
            price_trace: Vec::new(),
        })
    }

    /// Stable textual name — the CLI spelling back, recorded as the
    /// scenario label of every generated script.
    pub fn label(&self) -> String {
        match self.kind {
            HazardKind::Mtbf { mtbf_s, mttr_s } => format!("mtbf:{mtbf_s}:{mttr_s}"),
            HazardKind::Weibull {
                shape,
                scale_s,
                mttr_s,
            } => format!("weibull:{shape}:{scale_s}:{mttr_s}"),
            HazardKind::Group {
                mtbf_s,
                mttr_s,
                group,
            } => format!("group:{mtbf_s}:{mttr_s}:{group}"),
            HazardKind::Spot { bid_lo, bid_hi } => format!("spot:{bid_lo}:{bid_hi}"),
        }
    }

    /// Attach a warm-up (seconds) to every generated join.
    pub fn with_warmup(mut self, warmup_s: f64) -> anyhow::Result<Hazard> {
        if !warmup_s.is_finite() || warmup_s < 0.0 {
            anyhow::bail!("hazard warmup must be finite and >= 0, got {warmup_s}");
        }
        self.warmup_s = warmup_s;
        Ok(self)
    }

    /// Attach the price trace a [`HazardKind::Spot`] hazard replays.
    pub fn with_price_trace(mut self, trace: Vec<PricePoint>) -> Hazard {
        self.price_trace = trace;
        self
    }

    /// Generate one concrete outage script for the initial per-model
    /// fleet `counts` over `[0, horizon_s)`. Kills are capped at the
    /// horizon; the join repairing a kill may land past it (the
    /// simulator drains scripted events to the end, which is what
    /// guarantees parked work always flushes). Deterministic in every
    /// argument.
    pub fn generate(
        &self,
        counts: &[usize],
        horizon_s: f64,
        seed: u64,
    ) -> anyhow::Result<FailureScript> {
        if !horizon_s.is_finite() || horizon_s <= 0.0 {
            anyhow::bail!("hazard horizon must be positive and finite, got {horizon_s}");
        }
        let mut rng = Rng::new(seed ^ HAZARD_SEED_SALT);
        let mut events = Vec::new();
        // Flat model-major replica list: (model, replica) per seat.
        let seats: Vec<(usize, usize)> = counts
            .iter()
            .enumerate()
            .flat_map(|(k, &c)| (0..c).map(move |r| (k, r)))
            .collect();
        match self.kind {
            HazardKind::Mtbf { mtbf_s, mttr_s } => {
                for (i, &(k, r)) in seats.iter().enumerate() {
                    let mut sr = rng.fork(i as u64 + 1);
                    self.renewal(
                        &mut events,
                        &mut sr,
                        horizon_s,
                        &[(k, r)],
                        |g| g.exponential(1.0 / mtbf_s),
                        mttr_s,
                    );
                }
            }
            HazardKind::Weibull {
                shape,
                scale_s,
                mttr_s,
            } => {
                for (i, &(k, r)) in seats.iter().enumerate() {
                    let mut sr = rng.fork(i as u64 + 1);
                    self.renewal(
                        &mut events,
                        &mut sr,
                        horizon_s,
                        &[(k, r)],
                        |g| g.weibull(shape, scale_s),
                        mttr_s,
                    );
                }
            }
            HazardKind::Group {
                mtbf_s,
                mttr_s,
                group,
            } => {
                if group == 0 {
                    anyhow::bail!("hazard 'group': SIZE must be >= 1");
                }
                for (i, members) in seats.chunks(group).enumerate() {
                    let mut sr = rng.fork(i as u64 + 1);
                    self.renewal(
                        &mut events,
                        &mut sr,
                        horizon_s,
                        members,
                        |g| g.exponential(1.0 / mtbf_s),
                        mttr_s,
                    );
                }
            }
            HazardKind::Spot { bid_lo, bid_hi } => {
                if self.price_trace.is_empty() {
                    anyhow::bail!(
                        "hazard '{}' needs a price trace (--spot-trace FILE)",
                        self.label()
                    );
                }
                for (i, &(k, r)) in seats.iter().enumerate() {
                    let mut sr = rng.fork(i as u64 + 1);
                    let bid = sr.range(bid_lo, bid_hi);
                    let mut out = false;
                    let mut last_t = 0.0f64;
                    for p in &self.price_trace {
                        last_t = last_t.max(p.t_s);
                        if !out && p.price > bid && p.t_s < horizon_s {
                            events.push(FailureEvent {
                                t_s: p.t_s,
                                model: k,
                                replica: r,
                                kind: FailureKind::Kill,
                            });
                            out = true;
                        } else if out && p.price <= bid {
                            events.push(FailureEvent {
                                t_s: p.t_s,
                                model: k,
                                replica: r,
                                kind: FailureKind::Join {
                                    warmup_s: self.warmup_s,
                                },
                            });
                            out = false;
                        }
                    }
                    if out {
                        // The trace never came back under the bid: revive
                        // past the horizon so parked work still flushes.
                        events.push(FailureEvent {
                            t_s: last_t.max(horizon_s),
                            model: k,
                            replica: r,
                            kind: FailureKind::Join {
                                warmup_s: self.warmup_s,
                            },
                        });
                    }
                }
            }
        }
        Ok(FailureScript::new(events)?.with_label(self.label()))
    }

    /// One alternating up/down renewal process over `members` (all
    /// killed and revived at the same instants): uptimes from `up`,
    /// exponential repairs with mean `mttr_s`.
    fn renewal(
        &self,
        events: &mut Vec<FailureEvent>,
        rng: &mut Rng,
        horizon_s: f64,
        members: &[(usize, usize)],
        mut up: impl FnMut(&mut Rng) -> f64,
        mttr_s: f64,
    ) {
        let mut t = 0.0;
        loop {
            t += up(rng);
            if t >= horizon_s {
                return;
            }
            for &(k, r) in members {
                events.push(FailureEvent {
                    t_s: t,
                    model: k,
                    replica: r,
                    kind: FailureKind::Kill,
                });
            }
            t += rng.exponential(1.0 / mttr_s);
            for &(k, r) in members {
                events.push(FailureEvent {
                    t_s: t,
                    model: k,
                    replica: r,
                    kind: FailureKind::Join {
                        warmup_s: self.warmup_s,
                    },
                });
            }
        }
    }
}

/// Parse a JSONL spot-price trace (`--spot-trace FILE`): one object per
/// non-empty line with numeric `t` (seconds, non-decreasing) and `price`.
pub fn load_price_trace(text: &str) -> anyhow::Result<Vec<PricePoint>> {
    let mut points = Vec::new();
    let mut last: Option<(usize, f64)> = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("spot price trace line {}: {e}", lineno + 1))?;
        let t_s = v.get("t").as_f64().ok_or_else(|| {
            anyhow::anyhow!("spot price trace line {}: missing numeric 't'", lineno + 1)
        })?;
        if !t_s.is_finite() || t_s < 0.0 {
            anyhow::bail!(
                "spot price trace line {}: 't' must be finite and >= 0, got {t_s}",
                lineno + 1
            );
        }
        if let Some((prev_line, prev_t)) = last {
            if t_s < prev_t {
                anyhow::bail!(
                    "spot price trace line {}: non-monotone 't' {t_s} (line {prev_line} \
                     was {prev_t})",
                    lineno + 1
                );
            }
        }
        last = Some((lineno + 1, t_s));
        let price = v.get("price").as_f64().ok_or_else(|| {
            anyhow::anyhow!(
                "spot price trace line {}: missing numeric 'price'",
                lineno + 1
            )
        })?;
        if !price.is_finite() || price < 0.0 {
            anyhow::bail!(
                "spot price trace line {}: 'price' must be finite and >= 0, got {price}",
                lineno + 1
            );
        }
        points.push(PricePoint { t_s, price });
    }
    if points.is_empty() {
        anyhow::bail!("spot price trace is empty");
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_labels() {
        for spec in [
            "mtbf:600:60",
            "weibull:1.5:800:120",
            "group:900:90:4",
            "spot:0.2:0.8",
        ] {
            let h = Hazard::parse(spec).unwrap();
            assert_eq!(h.label(), spec);
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "mtbf",
            "mtbf:600",
            "mtbf:600:0",
            "mtbf:x:60",
            "mtbf:600:60:1",
            "weibull:1.5:800",
            "group:900:90:0.5",
            "spot:0.8:0.2",
            "spot:0.5:0.5",
            "quake:1",
            "",
        ] {
            assert!(Hazard::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn generation_is_deterministic_and_paired() {
        let h = Hazard::parse("mtbf:1.0:0.3").unwrap().with_warmup(0.1).unwrap();
        let a = h.generate(&[2, 1], 5.0, 42).unwrap();
        let b = h.generate(&[2, 1], 5.0, 42).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.label(), "mtbf:1:0.3");
        assert!(!a.is_empty(), "5 MTBFs of horizon should produce events");
        assert_ne!(a, h.generate(&[2, 1], 5.0, 43).unwrap());
        // Every kill is repaired: per (model, replica), kills and joins
        // alternate starting with a kill and end balanced.
        let mut open: std::collections::HashMap<(usize, usize), bool> = Default::default();
        for ev in a.events() {
            let down = open.entry((ev.model, ev.replica)).or_default();
            match ev.kind {
                FailureKind::Kill => {
                    assert!(!*down, "kill of an already-down replica");
                    assert!(ev.t_s < 5.0, "kill past horizon");
                    *down = true;
                }
                FailureKind::Join { warmup_s } => {
                    assert!(*down, "join of an up replica");
                    assert_eq!(warmup_s, 0.1);
                    *down = false;
                }
                FailureKind::Drain => unreachable!("hazards never drain"),
            }
        }
        assert!(open.values().all(|&d| !d), "unrepaired kill");
    }

    #[test]
    fn weibull_wearout_fails_more_than_young_shape() {
        // Same scale: shape 0.5 front-loads failures vs shape 3 within a
        // horizon shorter than the scale.
        let infant = Hazard::parse("weibull:0.5:10:0.5").unwrap();
        let wearout = Hazard::parse("weibull:3:10:0.5").unwrap();
        let counts = [8usize];
        let n_kills = |h: &Hazard| {
            (0..16)
                .map(|s| {
                    h.generate(&counts, 4.0, s)
                        .unwrap()
                        .events()
                        .iter()
                        .filter(|e| e.kind == FailureKind::Kill)
                        .count()
                })
                .sum::<usize>()
        };
        assert!(
            n_kills(&infant) > n_kills(&wearout),
            "infant-mortality shape should out-fail wear-out over a short horizon"
        );
    }

    #[test]
    fn group_hazard_downs_whole_groups_at_once() {
        let h = Hazard::parse("group:1.0:0.5:2").unwrap();
        // Fleet [2, 2] flattens to 4 seats → groups {(0,0),(0,1)} and
        // {(1,0),(1,1)}.
        let s = h.generate(&[2, 2], 6.0, 7).unwrap();
        assert!(!s.is_empty());
        let kills: Vec<&FailureEvent> = s
            .events()
            .iter()
            .filter(|e| e.kind == FailureKind::Kill)
            .collect();
        assert_eq!(kills.len() % 2, 0, "kills come in group pairs");
        for pair in kills.chunks(2) {
            assert_eq!(pair[0].t_s, pair[1].t_s, "group members die together");
            assert_eq!(pair[0].model, pair[1].model, "groups of 2 align to models here");
        }
    }

    #[test]
    fn spot_hazard_replays_price_crossings() {
        let trace = load_price_trace(
            "{\"t\": 0.0, \"price\": 0.1}\n\
             {\"t\": 1.0, \"price\": 0.9}\n\
             {\"t\": 2.0, \"price\": 0.1}\n\
             {\"t\": 3.0, \"price\": 0.9}\n",
        )
        .unwrap();
        // Bids drawn in [0.3, 0.5): every replica is outbid at t=1 and
        // t=3 and back under at t=2.
        let h = Hazard::parse("spot:0.3:0.5").unwrap().with_price_trace(trace);
        let s = h.generate(&[2], 10.0, 9).unwrap();
        let times: Vec<(f64, &'static str)> = s
            .events()
            .iter()
            .filter(|e| e.replica == 0)
            .map(|e| (e.t_s, e.kind.label()))
            .collect();
        // Kill at 1, join at 2, kill at 3, and the trace ends outbid →
        // synthetic join at max(last point, horizon) = 10.
        assert_eq!(
            times,
            vec![(1.0, "kill"), (2.0, "join"), (3.0, "kill"), (10.0, "join")]
        );
    }

    #[test]
    fn spot_without_trace_errors() {
        let err = Hazard::parse("spot:0.2:0.8")
            .unwrap()
            .generate(&[1], 1.0, 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--spot-trace"), "{err}");
    }

    #[test]
    fn price_trace_loader_names_line_and_field() {
        let err = load_price_trace("{\"t\": 0.0}\n").unwrap_err().to_string();
        assert_eq!(err, "spot price trace line 1: missing numeric 'price'");
        let err = load_price_trace(
            "{\"t\": 2.0, \"price\": 0.5}\n{\"t\": 1.0, \"price\": 0.5}\n",
        )
        .unwrap_err()
        .to_string();
        assert_eq!(
            err,
            "spot price trace line 2: non-monotone 't' 1 (line 1 was 2)"
        );
        let err = load_price_trace("{\"t\": 1.0, \"price\": -2}\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("'price' must be finite and >= 0"), "{err}");
        assert!(load_price_trace("\n\n").is_err());
    }
}
