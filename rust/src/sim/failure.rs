//! Failure and elasticity injection: a deterministic script of replica
//! lifecycle events the simulator replays on its virtual clock.
//!
//! The offline formulation assumes the cluster it planned for is the
//! cluster that serves. Real fleets are elastic — spot reclamation kills
//! a replica mid-batch, autoscalers join fresh ones after a warm-up, and
//! operators drain nodes for maintenance. A [`FailureScript`] makes those
//! events part of the simulation's pure-function inputs: the same script
//! plus the same seed replays byte-identically (enforced in
//! `tests/cluster.rs` and CI's chaos-smoke step), so replanning-under-
//! failure can be compared against a static plan under the *same* outage.
//!
//! Scripts are authored as JSONL (`--failures FILE`), one event per line:
//!
//! ```text
//! {"t": 1.5, "model": 0, "replica": 1, "kind": "kill"}
//! {"t": 2.0, "model": 1, "replica": 0, "kind": "drain"}
//! {"t": 3.0, "model": 0, "replica": 1, "kind": "join", "warmup": 0.5}
//! ```
//!
//! * **kill** — abrupt loss (spot reclamation, hardware fault): the
//!   replica's in-flight and queued work is requeued to its model's
//!   surviving replicas (original arrival times preserved; aborted work
//!   consumes no energy), counted in `requeued`.
//! * **drain** — graceful leave: the replica accepts no new work but
//!   finishes everything already queued; downtime starts at the drain
//!   instant.
//! * **join** — elasticity: the replica (a revived one, or the next fresh
//!   index for its model) becomes dispatchable after `warmup` seconds;
//!   the warm-up window counts as downtime.

use crate::util::Json;

/// What happens to the targeted replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailureKind {
    /// abrupt loss: in-flight and queued work requeues to siblings
    Kill,
    /// graceful leave: no new work, queued work completes
    Drain,
    /// (re)join after a warm-up delay, seconds
    Join { warmup_s: f64 },
}

impl FailureKind {
    /// The JSONL `kind` spelling.
    pub fn label(&self) -> &'static str {
        match self {
            FailureKind::Kill => "kill",
            FailureKind::Drain => "drain",
            FailureKind::Join { .. } => "join",
        }
    }
}

/// One scripted replica lifecycle event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    /// virtual time of the event, seconds
    pub t_s: f64,
    /// hosted-model index the replica belongs to
    pub model: usize,
    /// replica index within the model (model-major, 0-based)
    pub replica: usize,
    pub kind: FailureKind,
}

/// A validated, time-sorted script of [`FailureEvent`]s. Part of a
/// simulation's determinism contract: the script is replayed on the
/// virtual clock, with failure events winning ties against arrivals
/// (then engine events) at equal timestamps.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FailureScript {
    events: Vec<FailureEvent>,
}

impl FailureScript {
    /// Validate and time-sort (stable, so equal-time events keep their
    /// authored order).
    pub fn new(mut events: Vec<FailureEvent>) -> anyhow::Result<FailureScript> {
        for (i, ev) in events.iter().enumerate() {
            if !ev.t_s.is_finite() || ev.t_s < 0.0 {
                anyhow::bail!(
                    "failure event {i}: time must be finite and >= 0, got {}",
                    ev.t_s
                );
            }
            if let FailureKind::Join { warmup_s } = ev.kind {
                if !warmup_s.is_finite() || warmup_s < 0.0 {
                    anyhow::bail!(
                        "failure event {i}: join warmup must be finite and >= 0, got {warmup_s}"
                    );
                }
            }
        }
        events.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap());
        Ok(FailureScript { events })
    }

    /// Parse the JSONL form (`--failures FILE`): one object per
    /// non-empty line with keys `t`, `model`, `replica`, `kind`
    /// (`kill|drain|join`) and, for joins, an optional `warmup`
    /// (seconds, default 0).
    pub fn from_jsonl(text: &str) -> anyhow::Result<FailureScript> {
        let mut events = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let v = Json::parse(line).map_err(|e| {
                anyhow::anyhow!("failure script line {}: {e}", lineno + 1)
            })?;
            let t_s = v.get("t").as_f64().ok_or_else(|| {
                anyhow::anyhow!("failure script line {}: missing numeric 't'", lineno + 1)
            })?;
            let model = v.get("model").as_usize().ok_or_else(|| {
                anyhow::anyhow!("failure script line {}: missing integer 'model'", lineno + 1)
            })?;
            let replica = v.get("replica").as_usize().ok_or_else(|| {
                anyhow::anyhow!(
                    "failure script line {}: missing integer 'replica'",
                    lineno + 1
                )
            })?;
            let kind = match v.get("kind").as_str() {
                Some("kill") => FailureKind::Kill,
                Some("drain") => FailureKind::Drain,
                Some("join") => FailureKind::Join {
                    warmup_s: match v.get("warmup") {
                        Json::Null => 0.0,
                        w => w.as_f64().ok_or_else(|| {
                            anyhow::anyhow!(
                                "failure script line {}: non-numeric 'warmup'",
                                lineno + 1
                            )
                        })?,
                    },
                },
                other => anyhow::bail!(
                    "failure script line {}: unknown kind {:?} (expected kill|drain|join)",
                    lineno + 1,
                    other
                ),
            };
            events.push(FailureEvent {
                t_s,
                model,
                replica,
                kind,
            });
        }
        FailureScript::new(events)
    }

    pub fn events(&self) -> &[FailureEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Scenario label recorded in the metrics artifact (`chaos:N` for N
    /// scripted events; runs without a script record `none`).
    pub fn label(&self) -> String {
        format!("chaos:{}", self.events.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_roundtrip_and_sorting() {
        let text = r#"
            {"t": 3.0, "model": 0, "replica": 1, "kind": "join", "warmup": 0.5}
            {"t": 1.5, "model": 0, "replica": 1, "kind": "kill"}
            {"t": 2.0, "model": 1, "replica": 0, "kind": "drain"}
        "#;
        let s = FailureScript::from_jsonl(text).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.label(), "chaos:3");
        // Time-sorted regardless of authored order.
        assert_eq!(s.events()[0].t_s, 1.5);
        assert_eq!(s.events()[0].kind, FailureKind::Kill);
        assert_eq!(s.events()[1].kind, FailureKind::Drain);
        assert_eq!(s.events()[2].kind, FailureKind::Join { warmup_s: 0.5 });
    }

    #[test]
    fn join_warmup_defaults_to_zero() {
        let s = FailureScript::from_jsonl(
            r#"{"t": 0.0, "model": 0, "replica": 0, "kind": "join"}"#,
        )
        .unwrap();
        assert_eq!(s.events()[0].kind, FailureKind::Join { warmup_s: 0.0 });
    }

    #[test]
    fn stable_sort_keeps_equal_time_order() {
        let text = r#"
            {"t": 1.0, "model": 0, "replica": 0, "kind": "kill"}
            {"t": 1.0, "model": 1, "replica": 0, "kind": "kill"}
        "#;
        let s = FailureScript::from_jsonl(text).unwrap();
        assert_eq!(s.events()[0].model, 0);
        assert_eq!(s.events()[1].model, 1);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(FailureScript::from_jsonl("not json\n").is_err());
        let err = FailureScript::from_jsonl(r#"{"t": 1.0, "model": 0, "replica": 0}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("kind"), "{err}");
        let err = FailureScript::from_jsonl(
            r#"{"t": 1.0, "model": 0, "replica": 0, "kind": "explode"}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("explode"), "{err}");
        let err = FailureScript::from_jsonl(
            r#"{"t": -1.0, "model": 0, "replica": 0, "kind": "kill"}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains(">= 0"), "{err}");
        let err = FailureScript::from_jsonl(
            r#"{"t": 1.0, "model": 0, "replica": 0, "kind": "join", "warmup": -0.5}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("warmup"), "{err}");
    }
}
