//! Failure and elasticity injection: a deterministic script of replica
//! lifecycle events the simulator replays on its virtual clock.
//!
//! The offline formulation assumes the cluster it planned for is the
//! cluster that serves. Real fleets are elastic — spot reclamation kills
//! a replica mid-batch, autoscalers join fresh ones after a warm-up, and
//! operators drain nodes for maintenance. A [`FailureScript`] makes those
//! events part of the simulation's pure-function inputs: the same script
//! plus the same seed replays byte-identically (enforced in
//! `tests/cluster.rs` and CI's chaos-smoke step), so replanning-under-
//! failure can be compared against a static plan under the *same* outage.
//!
//! Scripts are authored as JSONL (`--failures FILE`), one event per line:
//!
//! ```text
//! {"t": 1.5, "model": 0, "replica": 1, "kind": "kill"}
//! {"t": 2.0, "model": 1, "replica": 0, "kind": "drain"}
//! {"t": 3.0, "model": 0, "replica": 1, "kind": "join", "warmup": 0.5}
//! ```
//!
//! * **kill** — abrupt loss (spot reclamation, hardware fault): the
//!   replica's in-flight and queued work is requeued to its model's
//!   surviving replicas (original arrival times preserved; aborted work
//!   consumes no energy), counted in `requeued`.
//! * **drain** — graceful leave: the replica accepts no new work but
//!   finishes everything already queued; downtime starts at the drain
//!   instant.
//! * **join** — elasticity: the replica (a revived one, or the next fresh
//!   index for its model) becomes dispatchable after `warmup` seconds;
//!   the warm-up window counts as downtime.

use crate::util::Json;

/// What happens to the targeted replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailureKind {
    /// abrupt loss: in-flight and queued work requeues to siblings
    Kill,
    /// graceful leave: no new work, queued work completes
    Drain,
    /// (re)join after a warm-up delay, seconds
    Join { warmup_s: f64 },
}

impl FailureKind {
    /// The JSONL `kind` spelling.
    pub fn label(&self) -> &'static str {
        match self {
            FailureKind::Kill => "kill",
            FailureKind::Drain => "drain",
            FailureKind::Join { .. } => "join",
        }
    }
}

/// One scripted replica lifecycle event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    /// virtual time of the event, seconds
    pub t_s: f64,
    /// hosted-model index the replica belongs to
    pub model: usize,
    /// replica index within the model (model-major, 0-based)
    pub replica: usize,
    pub kind: FailureKind,
}

/// A validated, time-sorted script of [`FailureEvent`]s. Part of a
/// simulation's determinism contract: the script is replayed on the
/// virtual clock, with failure events winning ties against arrivals
/// (then engine events) at equal timestamps.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FailureScript {
    events: Vec<FailureEvent>,
    /// scenario-label override (hazard generators stamp their spec here
    /// so the artifact records `mtbf:600:60` instead of `chaos:N`)
    label: Option<String>,
}

impl FailureScript {
    /// Validate and time-sort (stable, so equal-time events keep their
    /// authored order).
    pub fn new(mut events: Vec<FailureEvent>) -> anyhow::Result<FailureScript> {
        for (i, ev) in events.iter().enumerate() {
            if !ev.t_s.is_finite() || ev.t_s < 0.0 {
                anyhow::bail!(
                    "failure event {i}: time must be finite and >= 0, got {}",
                    ev.t_s
                );
            }
            if let FailureKind::Join { warmup_s } = ev.kind {
                if !warmup_s.is_finite() || warmup_s < 0.0 {
                    anyhow::bail!(
                        "failure event {i}: join warmup must be finite and >= 0, got {warmup_s}"
                    );
                }
            }
        }
        events.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap());
        Ok(FailureScript {
            events,
            label: None,
        })
    }

    /// Override the scenario label recorded in the metrics artifact.
    /// Hazard generators ([`crate::sim::Hazard`]) stamp their spec here.
    pub fn with_label(mut self, label: impl Into<String>) -> FailureScript {
        self.label = Some(label.into());
        self
    }

    /// Parse the JSONL form (`--failures FILE`): one object per
    /// non-empty line with keys `t`, `model`, `replica`, `kind`
    /// (`kill|drain|join`) and, for joins, an optional `warmup`
    /// (seconds, default 0). Authored timestamps must be non-decreasing
    /// — a script is a log of what happens, and an out-of-order line is
    /// almost always a typo'd time.
    pub fn from_jsonl(text: &str) -> anyhow::Result<FailureScript> {
        FailureScript::from_jsonl_with_fleet(text, None)
    }

    /// [`from_jsonl`](FailureScript::from_jsonl) with replica-range
    /// checking against the initial per-model fleet `counts`: kills and
    /// drains must target an existing replica index, and a join may
    /// revive a known index or append exactly the next fresh one (the
    /// fleet it grows is tracked line by line). Every rejection names
    /// the offending line and field.
    pub fn from_jsonl_with_fleet(
        text: &str,
        counts: Option<&[usize]>,
    ) -> anyhow::Result<FailureScript> {
        let mut events = Vec::new();
        let mut fleet: Option<Vec<usize>> = counts.map(|c| c.to_vec());
        let mut last: Option<(usize, f64)> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let v = Json::parse(line).map_err(|e| {
                anyhow::anyhow!("failure script line {}: {e}", lineno + 1)
            })?;
            let t_s = v.get("t").as_f64().ok_or_else(|| {
                anyhow::anyhow!("failure script line {}: missing numeric 't'", lineno + 1)
            })?;
            if let Some((prev_line, prev_t)) = last {
                if t_s < prev_t {
                    anyhow::bail!(
                        "failure script line {}: non-monotone 't' {t_s} \
                         (line {prev_line} was {prev_t}; events must be authored \
                         in time order)",
                        lineno + 1
                    );
                }
            }
            last = Some((lineno + 1, t_s));
            let model = v.get("model").as_usize().ok_or_else(|| {
                anyhow::anyhow!("failure script line {}: missing integer 'model'", lineno + 1)
            })?;
            let replica = v.get("replica").as_usize().ok_or_else(|| {
                anyhow::anyhow!(
                    "failure script line {}: missing integer 'replica'",
                    lineno + 1
                )
            })?;
            let kind = match v.get("kind").as_str() {
                Some("kill") => FailureKind::Kill,
                Some("drain") => FailureKind::Drain,
                Some("join") => FailureKind::Join {
                    warmup_s: match v.get("warmup") {
                        Json::Null => 0.0,
                        w => w.as_f64().ok_or_else(|| {
                            anyhow::anyhow!(
                                "failure script line {}: non-numeric 'warmup'",
                                lineno + 1
                            )
                        })?,
                    },
                },
                other => anyhow::bail!(
                    "failure script line {}: unknown kind {:?} (expected kill|drain|join)",
                    lineno + 1,
                    other
                ),
            };
            if let Some(fleet) = fleet.as_mut() {
                if model >= fleet.len() {
                    anyhow::bail!(
                        "failure script line {}: 'model' {model} out of range \
                         ({} hosted models)",
                        lineno + 1,
                        fleet.len()
                    );
                }
                match kind {
                    FailureKind::Kill | FailureKind::Drain => {
                        if replica >= fleet[model] {
                            anyhow::bail!(
                                "failure script line {}: 'replica' {replica} out of range \
                                 (model {model} has {} replicas at t={t_s})",
                                lineno + 1,
                                fleet[model]
                            );
                        }
                    }
                    FailureKind::Join { .. } => {
                        if replica > fleet[model] {
                            anyhow::bail!(
                                "failure script line {}: 'replica' {replica} skips ahead \
                                 (model {model}'s next fresh index at t={t_s} is {})",
                                lineno + 1,
                                fleet[model]
                            );
                        }
                        if replica == fleet[model] {
                            fleet[model] += 1;
                        }
                    }
                }
            }
            events.push(FailureEvent {
                t_s,
                model,
                replica,
                kind,
            });
        }
        FailureScript::new(events)
    }

    pub fn events(&self) -> &[FailureEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Scenario label recorded in the metrics artifact: the override
    /// stamped by [`with_label`](FailureScript::with_label) (hazard
    /// generators record their spec), else `chaos:N` for N scripted
    /// events; runs without a script record `none`.
    pub fn label(&self) -> String {
        self.label
            .clone()
            .unwrap_or_else(|| format!("chaos:{}", self.events.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_roundtrip() {
        let text = r#"
            {"t": 1.5, "model": 0, "replica": 1, "kind": "kill"}
            {"t": 2.0, "model": 1, "replica": 0, "kind": "drain"}
            {"t": 3.0, "model": 0, "replica": 1, "kind": "join", "warmup": 0.5}
        "#;
        let s = FailureScript::from_jsonl(text).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.label(), "chaos:3");
        assert_eq!(s.events()[0].t_s, 1.5);
        assert_eq!(s.events()[0].kind, FailureKind::Kill);
        assert_eq!(s.events()[1].kind, FailureKind::Drain);
        assert_eq!(s.events()[2].kind, FailureKind::Join { warmup_s: 0.5 });
    }

    #[test]
    fn programmatic_events_are_time_sorted() {
        // `new` (the hazard generators' entry point) still sorts; only
        // the authored JSONL form demands time order up front.
        let s = FailureScript::new(vec![
            FailureEvent {
                t_s: 3.0,
                model: 0,
                replica: 1,
                kind: FailureKind::Join { warmup_s: 0.5 },
            },
            FailureEvent {
                t_s: 1.5,
                model: 0,
                replica: 1,
                kind: FailureKind::Kill,
            },
        ])
        .unwrap();
        assert_eq!(s.events()[0].t_s, 1.5);
        assert_eq!(s.events()[1].t_s, 3.0);
    }

    #[test]
    fn jsonl_rejects_non_monotone_timestamps() {
        let text = r#"
            {"t": 3.0, "model": 0, "replica": 1, "kind": "join", "warmup": 0.5}
            {"t": 1.5, "model": 0, "replica": 1, "kind": "kill"}
        "#;
        let err = FailureScript::from_jsonl(text).unwrap_err().to_string();
        assert_eq!(
            err,
            "failure script line 3: non-monotone 't' 1.5 (line 2 was 3; \
             events must be authored in time order)"
        );
    }

    #[test]
    fn jsonl_fleet_checking_names_line_and_field() {
        let counts = [2usize, 1];
        // Kill of a replica the model never had.
        let err = FailureScript::from_jsonl_with_fleet(
            r#"{"t": 1.0, "model": 1, "replica": 1, "kind": "kill"}"#,
            Some(&counts),
        )
        .unwrap_err()
        .to_string();
        assert_eq!(
            err,
            "failure script line 1: 'replica' 1 out of range \
             (model 1 has 1 replicas at t=1)"
        );
        // Model index past the hosted set.
        let err = FailureScript::from_jsonl_with_fleet(
            r#"{"t": 1.0, "model": 2, "replica": 0, "kind": "drain"}"#,
            Some(&counts),
        )
        .unwrap_err()
        .to_string();
        assert_eq!(
            err,
            "failure script line 1: 'model' 2 out of range (2 hosted models)"
        );
        // Join skipping past the next fresh index.
        let err = FailureScript::from_jsonl_with_fleet(
            r#"{"t": 1.0, "model": 0, "replica": 3, "kind": "join"}"#,
            Some(&counts),
        )
        .unwrap_err()
        .to_string();
        assert_eq!(
            err,
            "failure script line 1: 'replica' 3 skips ahead \
             (model 0's next fresh index at t=1 is 2)"
        );
        // A join grows the tracked fleet, so later events may target it.
        let ok = FailureScript::from_jsonl_with_fleet(
            "{\"t\": 1.0, \"model\": 1, \"replica\": 1, \"kind\": \"join\"}\n\
             {\"t\": 2.0, \"model\": 1, \"replica\": 1, \"kind\": \"kill\"}\n",
            Some(&counts),
        )
        .unwrap();
        assert_eq!(ok.len(), 2);
    }

    #[test]
    fn label_override_survives_for_hazard_scenarios() {
        let s = FailureScript::new(vec![FailureEvent {
            t_s: 0.5,
            model: 0,
            replica: 0,
            kind: FailureKind::Kill,
        }])
        .unwrap()
        .with_label("mtbf:600:60");
        assert_eq!(s.label(), "mtbf:600:60");
    }

    #[test]
    fn join_warmup_defaults_to_zero() {
        let s = FailureScript::from_jsonl(
            r#"{"t": 0.0, "model": 0, "replica": 0, "kind": "join"}"#,
        )
        .unwrap();
        assert_eq!(s.events()[0].kind, FailureKind::Join { warmup_s: 0.0 });
    }

    #[test]
    fn stable_sort_keeps_equal_time_order() {
        let text = r#"
            {"t": 1.0, "model": 0, "replica": 0, "kind": "kill"}
            {"t": 1.0, "model": 1, "replica": 0, "kind": "kill"}
        "#;
        let s = FailureScript::from_jsonl(text).unwrap();
        assert_eq!(s.events()[0].model, 0);
        assert_eq!(s.events()[1].model, 1);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(FailureScript::from_jsonl("not json\n").is_err());
        let err = FailureScript::from_jsonl(r#"{"t": 1.0, "model": 0, "replica": 0}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("kind"), "{err}");
        let err = FailureScript::from_jsonl(
            r#"{"t": 1.0, "model": 0, "replica": 0, "kind": "explode"}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("explode"), "{err}");
        let err = FailureScript::from_jsonl(
            r#"{"t": -1.0, "model": 0, "replica": 0, "kind": "kill"}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains(">= 0"), "{err}");
        let err = FailureScript::from_jsonl(
            r#"{"t": 1.0, "model": 0, "replica": 0, "kind": "join", "warmup": -0.5}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("warmup"), "{err}");
    }
}
