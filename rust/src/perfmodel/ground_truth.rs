//! Ground-truth inference simulation — the stand-in for running a real
//! model on the Swing node. Produces a power/time trace that the telemetry
//! layer (simulated NVML + μProf) then *measures*, reproducing the paper's
//! estimation pipeline end to end.
//!
//! KV-caching across requests is disabled, as in §3: every request pays its
//! full prefill. Within a request the KV cache operates normally (that is
//! what "disable KV cache re-use" means in the paper's methodology: no
//! warm starts between trials).

use super::flops::{decode_step, prefill};
use super::phase::{run_phase, PhaseProfile};
use crate::config::LlmSpec;
use crate::hardware::Node;
use crate::util::Rng;

/// One homogeneous segment of the power trace.
#[derive(Debug, Clone, Copy)]
pub struct Segment {
    pub duration_s: f64,
    /// total GPU board power over all engaged GPUs, W
    pub gpu_w: f64,
    /// host cores active during the segment
    pub cpu_cores: u32,
    /// per-active-core load ∈ [0,1]
    pub cpu_load: f64,
}

/// The full trace of one inference request (batch).
#[derive(Debug, Clone)]
pub struct PowerTrace {
    pub segments: Vec<Segment>,
}

impl PowerTrace {
    pub fn runtime_s(&self) -> f64 {
        self.segments.iter().map(|s| s.duration_s).sum()
    }

    /// Exact GPU energy (J): ∫ P dt over the trace.
    pub fn gpu_energy_j(&self) -> f64 {
        self.segments.iter().map(|s| s.gpu_w * s.duration_s).sum()
    }
}

/// Simulation noise knobs. Defaults produce the "low variance renders
/// error bars invisible" regime of Figs. 1–2.
#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    /// multiplicative log-normal rel-sd on phase durations
    pub time_rel_sd: f64,
    /// multiplicative log-normal rel-sd on power draw
    pub power_rel_sd: f64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        NoiseModel {
            time_rel_sd: 0.02,
            power_rel_sd: 0.015,
        }
    }
}

/// The simulated cluster: node + noise, the object the characterization
/// campaign points its instruments at.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub node: Node,
    pub noise: NoiseModel,
}

impl Cluster {
    pub fn new(node: Node) -> Cluster {
        Cluster {
            node,
            noise: NoiseModel::default(),
        }
    }

    pub fn noiseless(node: Node) -> Cluster {
        Cluster {
            node,
            noise: NoiseModel {
                time_rel_sd: 0.0,
                power_rel_sd: 0.0,
            },
        }
    }

    /// Run one inference request: a batch of `batch` sequences, each with
    /// `t_in` prompt tokens, generating `t_out` tokens. Returns the power
    /// trace of the run.
    pub fn infer(
        &self,
        spec: &LlmSpec,
        t_in: u32,
        t_out: u32,
        batch: u32,
        rng: &mut Rng,
    ) -> PowerTrace {
        let tp = spec.n_gpus;
        let mut segments = Vec::with_capacity(t_out as usize + 2);

        // --- Host-side tokenize/setup (CPU only, GPUs idle). -------------
        let tok_s = 2e-3 + 8e-6 * t_in as f64 * batch as f64 / 32.0;
        segments.push(self.noisy(
            Segment {
                duration_s: tok_s,
                gpu_w: self.idle_gpu_w(tp),
                cpu_cores: 2,
                cpu_load: 0.9,
            },
            rng,
        ));

        // --- Prefill. -----------------------------------------------------
        let p = run_phase(spec, &self.node, &prefill(spec, t_in, batch), tp);
        segments.push(self.noisy(self.gpu_segment(&p), rng));

        // --- Decode steps (context grows each step). ----------------------
        // Exact per-step simulation; contexts c = t_in .. t_in + t_out − 1.
        for step in 0..t_out {
            let c = t_in + step;
            let d = run_phase(spec, &self.node, &decode_step(spec, c, batch), tp);
            segments.push(self.noisy(self.gpu_segment(&d), rng));
        }

        // --- Detokenize / host wrap-up. ------------------------------------
        let detok_s = 1e-3 + 2e-6 * t_out as f64 * batch as f64 / 32.0;
        segments.push(self.noisy(
            Segment {
                duration_s: detok_s,
                gpu_w: self.idle_gpu_w(tp),
                cpu_cores: 2,
                cpu_load: 0.8,
            },
            rng,
        ));

        PowerTrace { segments }
    }

    fn idle_gpu_w(&self, tp: u32) -> f64 {
        self.node.gpus[0].idle_w() * tp as f64
    }

    /// GPU-phase segment: all TP GPUs at the phase's power plus the host
    /// dispatch cores that HF-Accelerate-style serving keeps busy.
    fn gpu_segment(&self, p: &PhaseProfile) -> Segment {
        Segment {
            duration_s: p.duration_s,
            gpu_w: p.gpu_power_w * p.n_gpus as f64,
            cpu_cores: 2 + p.n_gpus,
            cpu_load: 0.45,
        }
    }

    fn noisy(&self, mut s: Segment, rng: &mut Rng) -> Segment {
        s.duration_s *= rng.noise_factor(self.noise.time_rel_sd);
        s.gpu_w *= rng.noise_factor(self.noise.power_rel_sd);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{lookup, swing_node};

    fn cluster() -> Cluster {
        Cluster::noiseless(Node::new(swing_node()))
    }

    #[test]
    fn runtime_grows_with_both_token_axes() {
        let c = cluster();
        let m = lookup("llama2-7b").unwrap();
        let mut rng = Rng::new(1);
        let base = c.infer(&m, 32, 32, 32, &mut rng).runtime_s();
        let more_in = c.infer(&m, 512, 32, 32, &mut rng).runtime_s();
        let more_out = c.infer(&m, 32, 512, 32, &mut rng).runtime_s();
        assert!(more_in > base);
        assert!(more_out > base);
        // Output tokens cost far more than input tokens (decode is
        // sequential) — the paper's central asymmetry.
        assert!(more_out > 4.0 * more_in, "{more_out} vs {more_in}");
    }

    #[test]
    fn energy_ordered_by_model_size() {
        let c = cluster();
        let mut rng = Rng::new(2);
        let mut e = |id: &str| {
            let m = lookup(id).unwrap();
            c.infer(&m, 128, 128, 32, &mut rng).gpu_energy_j()
        };
        let e7 = e("llama2-7b");
        let e13 = e("llama2-13b");
        let e70 = e("llama2-70b");
        assert!(e7 < e13 && e13 < e70, "{e7} {e13} {e70}");
    }

    #[test]
    fn mixtral_beats_falcon40b_on_energy() {
        // The paper's SMoE headline: Mixtral ≈ large-model accuracy at
        // smaller-model energy.
        let c = cluster();
        let mut rng = Rng::new(3);
        let mix = lookup("mixtral-8x7b").unwrap();
        let f40 = lookup("falcon-40b").unwrap();
        let em = c.infer(&mix, 1024, 256, 32, &mut rng).gpu_energy_j();
        let ef = c.infer(&f40, 1024, 256, 32, &mut rng).gpu_energy_j();
        assert!(em < ef, "mixtral {em} J vs falcon-40b {ef} J");
    }

    #[test]
    fn noiseless_is_deterministic() {
        let c = cluster();
        let m = lookup("mistral-7b").unwrap();
        let a = c.infer(&m, 64, 64, 32, &mut Rng::new(7)).runtime_s();
        let b = c.infer(&m, 64, 64, 32, &mut Rng::new(8)).runtime_s();
        assert_eq!(a, b);
    }

    #[test]
    fn noise_is_small_but_present() {
        let node = Node::new(swing_node());
        let c = Cluster::new(node);
        let m = lookup("falcon-7b").unwrap();
        let a = c.infer(&m, 64, 64, 32, &mut Rng::new(7)).runtime_s();
        let b = c.infer(&m, 64, 64, 32, &mut Rng::new(8)).runtime_s();
        assert_ne!(a, b);
        assert!((a - b).abs() / a < 0.05, "{a} vs {b}");
    }

    #[test]
    fn trace_accounts_all_time() {
        let c = cluster();
        let m = lookup("llama2-7b").unwrap();
        let trace = c.infer(&m, 16, 8, 32, &mut Rng::new(1));
        // tokenize + prefill + 8 decode steps + detokenize
        assert_eq!(trace.segments.len(), 11);
        assert!(trace.runtime_s() > 0.0);
        assert!(trace.gpu_energy_j() > 0.0);
    }
}
