//! Analytical FLOP and byte accounting for decoder-only transformer
//! inference, parameterized by the published architectures in the zoo.
//!
//! Conventions (standard in the inference-performance literature, e.g.
//! Pope et al., "Efficiently Scaling Transformer Inference"):
//! * linear-layer work is 2 FLOPs per parameter per token (MAC = 2);
//! * attention score+value work at context length `c` is `4·d_model·c`
//!   FLOPs per token per layer (2 for QKᵀ, 2 for A·V);
//! * decode reads every *active* weight byte once per step (weights are
//!   streamed from HBM; KV-cache reads grow with context).

use crate::config::LlmSpec;

/// Work and traffic of one inference phase on the full TP group.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Work {
    /// floating-point operations (per batch, all devices combined)
    pub flops: f64,
    /// HBM bytes moved (per batch, all devices combined)
    pub hbm_bytes: f64,
    /// bytes exchanged per tensor-parallel all-reduce (one collective)
    pub collective_bytes: f64,
    /// number of all-reduces in the phase
    pub n_collectives: f64,
}

/// Attention FLOPs per token per layer at context `c`.
fn attn_flops_per_token(spec: &LlmSpec, c: f64) -> f64 {
    4.0 * spec.arch.d_model as f64 * c
}

/// MoE router FLOPs per token per layer (gate projection + top-k select).
fn router_flops_per_token(spec: &LlmSpec) -> f64 {
    if spec.arch.is_moe() {
        2.0 * spec.arch.d_model as f64 * spec.arch.n_experts as f64
    } else {
        0.0
    }
}

/// Prefill: process `t_in` prompt tokens for a batch of `batch` sequences.
pub fn prefill(spec: &LlmSpec, t_in: u32, batch: u32) -> Work {
    let b = batch as f64;
    let n = t_in as f64;
    let l = spec.arch.n_layers as f64;
    let d = spec.arch.d_model as f64;

    // Linear layers: 2 FLOPs/param for each of the n tokens.
    let linear = 2.0 * spec.n_params_active as f64 * n;
    // Attention: Σ_{i=1..n} 4·d·i per layer ≈ 2·d·n² per layer.
    let attn = 2.0 * d * n * n * l;
    let router = router_flops_per_token(spec) * n * l;

    // Bytes: weights once (all experts are hit by a full prompt batch),
    // KV written for every token, activations ~2 passes of d per token.
    let weights = spec.weight_bytes() as f64;
    let kv_write = spec.kv_bytes_per_token() as f64 * n * b;
    let act = 4.0 * d * n * b * spec.arch.dtype_bytes as f64;

    Work {
        flops: b * (linear + attn + router),
        hbm_bytes: weights + kv_write + act,
        collective_bytes: b * n * d * spec.arch.dtype_bytes as f64,
        n_collectives: 2.0 * l,
    }
}

/// One decode step at context length `c` (tokens already in the KV cache)
/// for a batch of `batch` sequences.
pub fn decode_step(spec: &LlmSpec, c: u32, batch: u32) -> Work {
    let b = batch as f64;
    let l = spec.arch.n_layers as f64;
    let d = spec.arch.d_model as f64;
    let cf = c as f64;

    let linear = 2.0 * spec.n_params_active as f64;
    let attn = attn_flops_per_token(spec, cf) * l;
    let router = router_flops_per_token(spec) * l;

    // Weight traffic: dense models stream all weights once per step.
    // For MoE, the batch decides how many experts are touched: each of the
    // `b` tokens picks `experts_active` of `n_experts`, so the expected
    // number of unique experts loaded per layer is
    // E = n·(1 − (1 − k/n)^b) — at batch 32 effectively all of them.
    let weight_bytes = if spec.arch.is_moe() {
        let n_e = spec.arch.n_experts as f64;
        let k = spec.arch.experts_active as f64;
        let uniq = n_e * (1.0 - (1.0 - k / n_e).powf(b));
        let attn_and_shared = spec.active_weight_bytes() as f64
            - ffn_expert_bytes(spec) * spec.arch.experts_active as f64;
        attn_and_shared + ffn_expert_bytes(spec) * uniq
    } else {
        spec.weight_bytes() as f64
    };

    // KV reads: every cached token for every sequence in the batch.
    let kv_read = spec.kv_bytes_per_token() as f64 * cf * b;
    let kv_write = spec.kv_bytes_per_token() as f64 * b;
    let act = 4.0 * d * b * spec.arch.dtype_bytes as f64;

    Work {
        flops: b * (linear + attn + router),
        hbm_bytes: weight_bytes + kv_read + kv_write + act,
        collective_bytes: b * d * spec.arch.dtype_bytes as f64,
        n_collectives: 2.0 * l,
    }
}

/// Bytes of one FFN expert's weights (per layer × all layers).
fn ffn_expert_bytes(spec: &LlmSpec) -> f64 {
    let a = &spec.arch;
    // SwiGLU FFN: three projections d×d_ff.
    let per_layer = 3.0 * a.d_model as f64 * a.d_ff as f64;
    per_layer * a.n_layers as f64 * a.dtype_bytes as f64
}

/// Representative KV-cache context for a whole decode phase: a query that
/// prefills `t_in` tokens and generates `t_out` walks contexts
/// `t_in..t_in+t_out`, so the phase-average decode step runs at the
/// midpoint. Summarizing the phase by one step at this context keeps the
/// (linear-in-`c`) KV-read term exact in expectation while costing one
/// roofline evaluation instead of `t_out`.
pub fn mean_decode_context(t_in: u32, t_out: u32) -> u32 {
    t_in.saturating_add(t_out / 2)
}

/// Arithmetic intensity (FLOPs per HBM byte) — used by perf analysis and
/// the §Perf roofline discussion.
pub fn intensity(w: &Work) -> f64 {
    if w.hbm_bytes > 0.0 {
        w.flops / w.hbm_bytes
    } else {
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::lookup;

    #[test]
    fn prefill_scales_superlinearly() {
        let m = lookup("llama2-7b").unwrap();
        let w1 = prefill(&m, 128, 32);
        let w2 = prefill(&m, 256, 32);
        // Doubling input more than doubles FLOPs (quadratic attention term).
        assert!(w2.flops > 2.0 * w1.flops);
        assert!(w2.flops < 4.0 * w1.flops);
    }

    #[test]
    fn prefill_flops_near_2pn_for_short_prompts() {
        // For short prompts the 2·P·n linear term dominates.
        let m = lookup("llama2-7b").unwrap();
        let w = prefill(&m, 32, 1);
        let linear = 2.0 * m.n_params as f64 * 32.0;
        assert!((w.flops - linear).abs() / linear < 0.05, "{}", w.flops / linear);
    }

    #[test]
    fn decode_step_memory_bound() {
        // Decode at batch 32 still has intensity far below the A100
        // compute/bandwidth balance point (~200 FLOP/B at datasheet values).
        let m = lookup("llama2-13b").unwrap();
        let w = decode_step(&m, 512, 32);
        assert!(intensity(&w) < 150.0, "intensity={}", intensity(&w));
        // Prefill of a long prompt is compute-bound.
        let wp = prefill(&m, 1024, 32);
        assert!(intensity(&wp) > 300.0, "intensity={}", intensity(&wp));
    }

    #[test]
    fn decode_bytes_grow_with_context() {
        let m = lookup("mistral-7b").unwrap();
        let w1 = decode_step(&m, 64, 32);
        let w2 = decode_step(&m, 2048, 32);
        assert!(w2.hbm_bytes > w1.hbm_bytes);
        // Weight streaming dominates at short context.
        assert!(w1.hbm_bytes > m.weight_bytes() as f64);
    }

    #[test]
    fn moe_decode_flops_much_lower_than_dense_peer() {
        // Mixtral's active params ≈ 12.9B vs Falcon-40B's 41.8B → about 3×
        // fewer decode FLOPs, while weight traffic stays comparable.
        let mix = lookup("mixtral-8x7b").unwrap();
        let f40 = lookup("falcon-40b").unwrap();
        let wm = decode_step(&mix, 256, 32);
        let wf = decode_step(&f40, 256, 32);
        assert!(wm.flops < 0.45 * wf.flops, "{} vs {}", wm.flops, wf.flops);
        assert!(wm.hbm_bytes > 0.5 * wf.hbm_bytes);
    }

    #[test]
    fn moe_prefill_flops_lower_than_dense_peer() {
        let mix = lookup("mixtral-8x7b").unwrap();
        let f40 = lookup("falcon-40b").unwrap();
        let wm = prefill(&mix, 1024, 32);
        let wf = prefill(&f40, 1024, 32);
        assert!(wm.flops < 0.5 * wf.flops);
    }

    #[test]
    fn moe_unique_experts_saturate_at_batch() {
        let mix = lookup("mixtral-8x7b").unwrap();
        // Batch 1: only k experts loaded → much less weight traffic than
        // batch 32 (≈ all experts).
        let w1 = decode_step(&mix, 128, 1);
        let w32 = decode_step(&mix, 128, 32);
        assert!(w1.hbm_bytes < 0.55 * w32.hbm_bytes, "{} vs {}", w1.hbm_bytes, w32.hbm_bytes);
    }

    #[test]
    fn mean_decode_context_is_the_phase_midpoint() {
        assert_eq!(mean_decode_context(128, 256), 256);
        assert_eq!(mean_decode_context(128, 0), 128);
        // Saturates instead of wrapping on adversarial token counts.
        assert_eq!(mean_decode_context(u32::MAX, u32::MAX), u32::MAX);
    }

    #[test]
    fn collectives_scale_with_layers() {
        let m = lookup("llama2-70b").unwrap();
        let w = decode_step(&m, 100, 32);
        assert_eq!(w.n_collectives, 160.0);
    }
}
