//! Analytical performance model of LLM inference on the simulated node:
//! FLOP/byte accounting per phase, roofline latency + power per phase, and
//! the ground-truth trace generator the telemetry layer measures.

pub mod flops;
pub mod ground_truth;
pub mod phase;

pub use flops::{decode_step, intensity, mean_decode_context, prefill, Work};
pub use ground_truth::{Cluster, NoiseModel, PowerTrace, Segment};
pub use phase::{dispatch_overhead_s, query_phases, run_phase, PhaseProfile, QueryPhases};
