//! Phase latency and power: maps the FLOP/byte `Work` of a phase onto a
//! tensor-parallel GPU group through the roofline model, yielding duration
//! and average board power per GPU.

use super::flops::{decode_step, mean_decode_context, prefill, Work};
use crate::config::LlmSpec;
use crate::hardware::Node;

/// Execution profile of one phase (prefill or a decode step).
#[derive(Debug, Clone, Copy)]
pub struct PhaseProfile {
    pub duration_s: f64,
    /// per-GPU average board power over the phase, W
    pub gpu_power_w: f64,
    /// number of GPUs engaged
    pub n_gpus: u32,
    /// compute / memory utilization (diagnostics, §Perf)
    pub u_compute: f64,
    pub u_memory: f64,
}

/// Fixed software overhead per phase: the HF-Accelerate-style Python
/// dispatch loop issues each layer's kernels step by step. Per decode step
/// this is a constant; it is what produces the flat per-token floor small
/// models exhibit in Fig. 2.
pub fn dispatch_overhead_s(spec: &LlmSpec, node: &Node) -> f64 {
    // ~6 kernel launches per layer + sampling/copy at the step boundary.
    let launches = 6.0 * spec.arch.n_layers as f64 + 12.0;
    let moe_extra = if spec.arch.is_moe() {
        // gather/scatter routing adds two launches per layer
        2.0 * spec.arch.n_layers as f64
    } else {
        0.0
    };
    (launches + moe_extra) * node.spec.launch_overhead_s
}

/// Execute a phase's `Work` on `tp` GPUs of the node.
pub fn run_phase(spec: &LlmSpec, node: &Node, work: &Work, tp: u32) -> PhaseProfile {
    let gpu = &node.gpus[0];
    // Work shards evenly across the TP group.
    let flops = work.flops / tp as f64;
    let bytes = work.hbm_bytes / tp as f64;
    let t_kernel = gpu.kernel_time_s(flops, bytes);
    let t_comm = node.allreduce_time_s(tp, work.collective_bytes) * work.n_collectives;
    let t_overhead = dispatch_overhead_s(spec, node);
    let duration = t_kernel + t_comm + t_overhead;

    // Utilization over the *whole* phase (overheads dilute it).
    let (u_c_kernel, u_m_kernel) = gpu.utilization(flops, bytes);
    let dilution = if duration > 0.0 { t_kernel / duration } else { 0.0 };
    let u_c = u_c_kernel * dilution;
    let u_m = u_m_kernel * dilution;

    PhaseProfile {
        duration_s: duration,
        gpu_power_w: gpu.power_w(u_c, u_m),
        n_gpus: tp,
        u_compute: u_c,
        u_memory: u_m,
    }
}

/// Roofline decomposition of one whole query at batch 1: the prefill pass
/// over `t_in` prompt tokens, then `t_out` decode steps summarized by one
/// representative step at the phase-mean KV context
/// ([`mean_decode_context`]).
///
/// The simulator's continuous-batching engine uses the *ratios* of these
/// phase costs to split each query's fitted whole-query `r_K`/`e_K`
/// prediction into an iteration-level prefill chunk and per-token decode
/// steps — the fitted totals stay the source of truth (and the lockstep
/// cross-check), while the roofline supplies the phase proportions the
/// bilinear models cannot see.
#[derive(Debug, Clone, Copy)]
pub struct QueryPhases {
    /// prefill duration, s (batch 1)
    pub prefill_s: f64,
    /// duration of one decode step at the mean context, s (batch 1)
    pub decode_step_s: f64,
    /// board energy of the prefill phase across the TP group, J
    pub prefill_j: f64,
    /// board energy of all `t_out` decode steps across the TP group, J
    pub decode_j: f64,
}

/// Decompose a `(t_in, t_out)` query on `spec`'s native TP degree.
pub fn query_phases(spec: &LlmSpec, node: &Node, t_in: u32, t_out: u32) -> QueryPhases {
    let tp = spec.n_gpus;
    let pre = run_phase(spec, node, &prefill(spec, t_in.max(1), 1), tp);
    let c = mean_decode_context(t_in, t_out);
    let dec = run_phase(spec, node, &decode_step(spec, c, 1), tp);
    let gpus = tp as f64;
    QueryPhases {
        prefill_s: pre.duration_s,
        decode_step_s: dec.duration_s,
        prefill_j: pre.duration_s * pre.gpu_power_w * gpus,
        decode_j: dec.duration_s * dec.gpu_power_w * gpus * t_out as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{lookup, swing_node};
    use crate::perfmodel::flops::{decode_step, prefill};

    fn node() -> Node {
        Node::new(swing_node())
    }

    #[test]
    fn prefill_high_power_decode_lower() {
        let m = lookup("llama2-7b").unwrap();
        let n = node();
        let p_pre = run_phase(&m, &n, &prefill(&m, 1024, 32), m.n_gpus);
        let p_dec = run_phase(&m, &n, &decode_step(&m, 1024, 32), m.n_gpus);
        assert!(
            p_pre.gpu_power_w > p_dec.gpu_power_w,
            "prefill {} W vs decode {} W",
            p_pre.gpu_power_w,
            p_dec.gpu_power_w
        );
        assert!(p_pre.u_compute > 0.8);
        assert!(p_dec.u_memory > 0.5);
    }

    #[test]
    fn overhead_floors_small_models() {
        // At trivial context the decode step cost approaches the dispatch
        // overhead floor.
        let m = lookup("llama2-7b").unwrap();
        let n = node();
        let p = run_phase(&m, &n, &decode_step(&m, 8, 1), m.n_gpus);
        let floor = dispatch_overhead_s(&m, &n);
        assert!(p.duration_s < 3.0 * floor, "{} vs floor {}", p.duration_s, floor);
    }

    #[test]
    fn tp_speeds_up_kernels() {
        let m = lookup("llama2-70b").unwrap();
        let n = node();
        let w = prefill(&m, 2048, 32);
        let t4 = run_phase(&m, &n, &w, 4).duration_s;
        let t1 = run_phase(&m, &n, &w, 1).duration_s;
        assert!(t4 < t1);
        assert!(t4 > t1 / 4.0); // comm + overhead prevent perfect scaling
    }

    #[test]
    fn query_phases_split_tracks_workload_shape() {
        let m = lookup("llama2-7b").unwrap();
        let n = node();
        // Long prompt, one token out: prefill dominates both time and energy.
        let long_in = query_phases(&m, &n, 4096, 1);
        assert!(long_in.prefill_s > long_in.decode_step_s);
        assert!(long_in.prefill_j > long_in.decode_j);
        // Short prompt, long generation: the decode phase dominates.
        let long_out = query_phases(&m, &n, 16, 1024);
        let decode_total_s = 1024.0 * long_out.decode_step_s;
        assert!(decode_total_s > long_out.prefill_s);
        assert!(long_out.decode_j > long_out.prefill_j);
        // All components finite and non-negative; zero generation means
        // zero decode energy.
        let no_decode = query_phases(&m, &n, 64, 0);
        assert!(no_decode.prefill_s > 0.0 && no_decode.prefill_j > 0.0);
        assert_eq!(no_decode.decode_j, 0.0);
    }

    #[test]
    fn durations_realistic_magnitude() {
        // Llama-2 7B, 32-token prompt, one decode step at batch 32: each
        // decode step streams 13.5 GB of weights over ~1.2 TB/s → ≳11 ms.
        let m = lookup("llama2-7b").unwrap();
        let n = node();
        let p = run_phase(&m, &n, &decode_step(&m, 32, 32), 1);
        assert!(p.duration_s > 0.008 && p.duration_s < 0.08, "{}", p.duration_s);
    }
}
