//! The experimental campaign of §5.1: vary-input sweeps (Fig. 1),
//! vary-output sweeps (Fig. 2), and the full τ_in × τ_out grid used for the
//! ANOVA (Table 2) and the model fits (Table 3).
//!
//! Faithful to the paper's protocol: batch size fixed at 32, KV cache cold
//! per trial, experiment cells visited in randomized order, and trials per
//! cell governed by the 95%-CI / 25-trial stopping rule (§5.1.3).

use crate::config::{epyc_7742, ExperimentConfig, LlmSpec};
use crate::hardware::Cpu;
use crate::perfmodel::Cluster;
use crate::stats::{StopReason, StoppingRule};
use crate::telemetry::{measure, Measurement};
use crate::util::Rng;

/// All trials of one experiment cell (model × τ_in × τ_out).
#[derive(Debug, Clone)]
pub struct Cell {
    pub model_id: String,
    pub t_in: u32,
    pub t_out: u32,
    pub batch: u32,
    pub trials: Vec<Measurement>,
    pub stop: StopReason,
}

impl Cell {
    pub fn mean_runtime_s(&self) -> f64 {
        self.trials.iter().map(|m| m.runtime_s).sum::<f64>() / self.trials.len() as f64
    }

    pub fn mean_energy_j(&self) -> f64 {
        self.trials.iter().map(|m| m.total_energy_j()).sum::<f64>() / self.trials.len() as f64
    }

    pub fn mean_gpu_energy_j(&self) -> f64 {
        self.trials.iter().map(|m| m.gpu_energy_j).sum::<f64>() / self.trials.len() as f64
    }

    pub fn mean_cpu_energy_j(&self) -> f64 {
        self.trials.iter().map(|m| m.cpu_energy_j).sum::<f64>() / self.trials.len() as f64
    }

    /// Tokens processed per wall-second (prompt + generated, whole batch).
    pub fn throughput_tok_s(&self) -> f64 {
        let tokens = (self.t_in + self.t_out) as f64 * self.batch as f64;
        tokens / self.mean_runtime_s()
    }

    /// Energy per processed token (J/token) — the Fig. 1/2 bottom panels.
    pub fn energy_per_token_j(&self) -> f64 {
        let tokens = (self.t_in + self.t_out) as f64 * self.batch as f64;
        self.mean_energy_j() / tokens
    }
}

/// Campaign driver bound to a simulated cluster.
pub struct Campaign {
    pub cluster: Cluster,
    pub cpu: Cpu,
    pub rule: StoppingRule,
    pub cfg: ExperimentConfig,
}

impl Campaign {
    pub fn new(cluster: Cluster, cfg: ExperimentConfig) -> Campaign {
        Campaign {
            cluster,
            cpu: Cpu::new(epyc_7742(), 0),
            rule: StoppingRule::default(),
            cfg,
        }
    }

    /// Measure one cell under the stopping rule.
    pub fn run_cell(&self, spec: &LlmSpec, t_in: u32, t_out: u32, rng: &mut Rng) -> Cell {
        let mut trials: Vec<Measurement> = Vec::new();
        let stop = loop {
            let runtimes: Vec<f64> = trials.iter().map(|m| m.runtime_s).collect();
            match self.rule.check(&runtimes) {
                StopReason::Continue => {
                    let trace = self.cluster.infer(spec, t_in, t_out, self.cfg.batch_size, rng);
                    trials.push(measure(&trace, &self.cpu, rng));
                }
                reason => break reason,
            }
        };
        Cell {
            model_id: spec.id.to_string(),
            t_in,
            t_out,
            batch: self.cfg.batch_size,
            trials,
            stop,
        }
    }

    /// §5.1.1 — vary input tokens with output fixed at 32.
    pub fn sweep_input(&self, spec: &LlmSpec, rng: &mut Rng) -> Vec<Cell> {
        let mut levels = self.cfg.input_sweep.clone();
        rng.shuffle(&mut levels); // §5.1.3 randomized order
        let mut cells: Vec<Cell> = levels
            .iter()
            .map(|&t_in| self.run_cell(spec, t_in, self.cfg.fixed_output, rng))
            .collect();
        cells.sort_by_key(|c| c.t_in);
        cells
    }

    /// §5.1.2 — vary output tokens with input fixed at 32.
    pub fn sweep_output(&self, spec: &LlmSpec, rng: &mut Rng) -> Vec<Cell> {
        let mut levels = self.cfg.output_sweep.clone();
        rng.shuffle(&mut levels);
        let mut cells: Vec<Cell> = levels
            .iter()
            .map(|&t_out| self.run_cell(spec, self.cfg.fixed_input, t_out, rng))
            .collect();
        cells.sort_by_key(|c| c.t_out);
        cells
    }

    /// §6.1 — full grid over τ_in × τ_out (powers of two), randomized
    /// visit order. `trials_per_cell` overrides the stopping rule's cap to
    /// bound grid cost (the rule still applies within the cap).
    pub fn grid(&self, spec: &LlmSpec, trials_per_cell: usize, rng: &mut Rng) -> Vec<Cell> {
        let mut points: Vec<(u32, u32)> = Vec::new();
        for &a in &self.cfg.grid_levels {
            for &b in &self.cfg.grid_levels {
                points.push((a, b));
            }
        }
        rng.shuffle(&mut points);
        let capped = Campaign {
            cluster: self.cluster.clone(),
            cpu: self.cpu.clone(),
            rule: StoppingRule {
                max_trials: trials_per_cell,
                ..self.rule
            },
            cfg: self.cfg.clone(),
        };
        let mut cells: Vec<Cell> = points
            .iter()
            .map(|&(t_in, t_out)| capped.run_cell(spec, t_in, t_out, rng))
            .collect();
        cells.sort_by_key(|c| (c.t_in, c.t_out));
        cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{lookup, swing_node};
    use crate::hardware::Node;

    fn campaign() -> Campaign {
        Campaign::new(
            Cluster::new(Node::new(swing_node())),
            ExperimentConfig::default(),
        )
    }

    #[test]
    fn cell_obeys_stopping_rule() {
        let c = campaign();
        let m = lookup("llama2-7b").unwrap();
        let cell = c.run_cell(&m, 64, 32, &mut Rng::new(1));
        assert!(cell.trials.len() >= c.rule.min_trials);
        assert!(cell.trials.len() <= c.rule.max_trials);
        assert_ne!(cell.stop, StopReason::Continue);
    }

    #[test]
    fn sweep_input_covers_levels_sorted() {
        let c = campaign();
        let m = lookup("falcon-7b").unwrap();
        let cells = c.sweep_input(&m, &mut Rng::new(2));
        let t_ins: Vec<u32> = cells.iter().map(|c| c.t_in).collect();
        assert_eq!(t_ins, c.cfg.input_sweep);
        assert!(cells.iter().all(|c| c.t_out == 32));
    }

    #[test]
    fn runtime_monotone_in_output_tokens() {
        let c = campaign();
        let m = lookup("mistral-7b").unwrap();
        let cells = c.sweep_output(&m, &mut Rng::new(3));
        let runtimes: Vec<f64> = cells.iter().map(|c| c.mean_runtime_s()).collect();
        assert!(
            runtimes.windows(2).all(|w| w[1] > w[0]),
            "runtimes={runtimes:?}"
        );
    }

    #[test]
    fn throughput_plateaus_on_input_sweep() {
        // Fig. 1 middle panel: throughput grows then flattens (roofline).
        let c = campaign();
        let m = lookup("llama2-7b").unwrap();
        let cells = c.sweep_input(&m, &mut Rng::new(4));
        let tp: Vec<f64> = cells.iter().map(|c| c.throughput_tok_s()).collect();
        assert!(tp.last().unwrap() > tp.first().unwrap());
        // Ratio of successive gains shrinks (concavity/plateau).
        let gain_early = tp[2] / tp[0];
        let gain_late = tp[tp.len() - 1] / tp[tp.len() - 3];
        assert!(gain_early > gain_late, "early {gain_early} late {gain_late}");
    }

    #[test]
    fn grid_covers_all_cells() {
        let mut cfg = ExperimentConfig::default();
        cfg.grid_levels = vec![8, 64, 512];
        let c = Campaign::new(Cluster::new(Node::new(swing_node())), cfg);
        let m = lookup("llama2-7b").unwrap();
        let cells = c.grid(&m, 3, &mut Rng::new(5));
        assert_eq!(cells.len(), 9);
        assert!(cells.iter().all(|c| c.trials.len() <= 3));
    }
}
