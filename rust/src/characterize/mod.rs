//! Characterization study (§5): the experimental campaign over the
//! simulated cluster and the persistence of its measurements.

pub mod campaign;
pub mod dataset;
pub mod pipeline;

pub use campaign::{Campaign, Cell};
pub use pipeline::{characterize_and_fit, quick_fit, PipelineOutput};
pub use dataset::{anova_blocks, anova_obs, from_csv, load, regression_design, rows_from_cells, save, to_csv, Row};
