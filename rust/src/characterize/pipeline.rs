//! Convenience pipeline: the standard "characterize → fit" sequence shared
//! by the CLI, the examples and the benches, so every consumer runs the
//! identical protocol.

use super::campaign::Campaign;
use super::dataset::{rows_from_cells, Row};
use crate::config::{swing_node, ExperimentConfig, LlmSpec};
use crate::hardware::Node;
use crate::models::{fit_all, ModelSet};
use crate::perfmodel::Cluster;
use crate::util::Rng;

/// Result of the standard pipeline.
pub struct PipelineOutput {
    pub rows: Vec<Row>,
    pub sets: Vec<ModelSet>,
}

/// Run the grid campaign for `specs` and fit e_K/r_K per model.
pub fn characterize_and_fit(
    specs: &[LlmSpec],
    cfg: &ExperimentConfig,
    trials_per_cell: usize,
    rng: &mut Rng,
) -> anyhow::Result<PipelineOutput> {
    let campaign = Campaign::new(Cluster::new(Node::new(swing_node())), cfg.clone());
    let mut rows = Vec::new();
    for spec in specs {
        crate::info!("characterizing {} over the token grid", spec.id);
        let cells = campaign.grid(spec, trials_per_cell, rng);
        rows.extend(rows_from_cells(&cells));
    }
    let sets = fit_all(specs, &rows)?;
    Ok(PipelineOutput { rows, sets })
}

/// A faster, coarser pipeline for examples/quick runs: 5-level grid.
pub fn quick_fit(specs: &[LlmSpec], seed: u64) -> anyhow::Result<PipelineOutput> {
    let mut cfg = ExperimentConfig::default();
    cfg.grid_levels = vec![8, 32, 128, 512, 2048];
    let mut rng = Rng::new(seed);
    characterize_and_fit(specs, &cfg, 3, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::llama_family;

    #[test]
    fn quick_fit_clears_r2_bar_for_family() {
        let out = quick_fit(&llama_family(), 7).unwrap();
        assert_eq!(out.sets.len(), 3);
        for s in &out.sets {
            assert!(s.energy.r2 > 0.96, "{}: {}", s.model_id, s.energy.r2);
            assert!(s.runtime.r2 > 0.96, "{}: {}", s.model_id, s.runtime.r2);
        }
        // Larger Llama-2 = more energy per output token (β1 ordering).
        let a1: Vec<f64> = out.sets.iter().map(|s| s.energy.coefs[1]).collect();
        assert!(a1[0] < a1[1] && a1[1] < a1[2], "{a1:?}");
    }
}
