//! Persistence of characterization data: flat trial-level records, CSV
//! round-trip, and conversion to the shapes the stats layer consumes.
//! This mirrors the role of the CSV datasets the paper's profiling
//! framework publishes.

use super::campaign::Cell;
use crate::stats::anova::Obs;
use std::path::Path;

/// One trial-level row (the unit of fitting — each trial is an
/// observation, as in the paper's OLS over all collected runs).
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    pub model_id: String,
    pub t_in: u32,
    pub t_out: u32,
    pub batch: u32,
    pub trial: u32,
    pub runtime_s: f64,
    pub gpu_energy_j: f64,
    pub cpu_energy_j: f64,
}

impl Row {
    pub fn total_energy_j(&self) -> f64 {
        self.gpu_energy_j + self.cpu_energy_j
    }
}

/// Flatten measured cells to trial rows.
pub fn rows_from_cells(cells: &[Cell]) -> Vec<Row> {
    let mut rows = Vec::new();
    for c in cells {
        for (i, t) in c.trials.iter().enumerate() {
            rows.push(Row {
                model_id: c.model_id.clone(),
                t_in: c.t_in,
                t_out: c.t_out,
                batch: c.batch,
                trial: i as u32,
                runtime_s: t.runtime_s,
                gpu_energy_j: t.gpu_energy_j,
                cpu_energy_j: t.cpu_energy_j,
            });
        }
    }
    rows
}

const HEADER: &str = "model,t_in,t_out,batch,trial,runtime_s,gpu_energy_j,cpu_energy_j";

/// Serialize rows to CSV text.
pub fn to_csv(rows: &[Row]) -> String {
    let mut out = String::from(HEADER);
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{:.9},{:.6},{:.6}\n",
            r.model_id,
            r.t_in,
            r.t_out,
            r.batch,
            r.trial,
            r.runtime_s,
            r.gpu_energy_j,
            r.cpu_energy_j
        ));
    }
    out
}

/// Parse rows from CSV text (inverse of [`to_csv`]).
pub fn from_csv(text: &str) -> anyhow::Result<Vec<Row>> {
    let mut lines = text.lines();
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty csv"))?;
    if header.trim() != HEADER {
        anyhow::bail!("unexpected csv header: {header}");
    }
    let mut rows = Vec::new();
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 8 {
            anyhow::bail!("line {}: expected 8 fields, got {}", ln + 2, f.len());
        }
        rows.push(Row {
            model_id: f[0].to_string(),
            t_in: f[1].parse()?,
            t_out: f[2].parse()?,
            batch: f[3].parse()?,
            trial: f[4].parse()?,
            runtime_s: f[5].parse()?,
            gpu_energy_j: f[6].parse()?,
            cpu_energy_j: f[7].parse()?,
        });
    }
    Ok(rows)
}

/// Write rows to a file.
pub fn save(rows: &[Row], path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, to_csv(rows))?;
    Ok(())
}

/// Read rows from a file.
pub fn load(path: &Path) -> anyhow::Result<Vec<Row>> {
    from_csv(&std::fs::read_to_string(path)?)
}

/// Project rows into ANOVA observations with τ_in as factor A and τ_out as
/// factor B. `metric` selects the response.
pub fn anova_obs<F: Fn(&Row) -> f64>(rows: &[Row], metric: F) -> Vec<Obs> {
    rows.iter()
        .map(|r| Obs {
            a: r.t_in,
            b: r.t_out,
            y: metric(r),
        })
        .collect()
}

/// ANOVA observations grouped per model (blocks for
/// `stats::two_way_blocked` — the Table-2 "aggregated across all models"
/// analysis with model as the blocking factor).
pub fn anova_blocks<F: Fn(&Row) -> f64>(rows: &[Row], metric: F) -> Vec<Vec<Obs>> {
    let mut ids: Vec<&str> = rows.iter().map(|r| r.model_id.as_str()).collect();
    ids.sort();
    ids.dedup();
    ids.iter()
        .map(|id| {
            rows.iter()
                .filter(|r| r.model_id == *id)
                .map(|r| Obs {
                    a: r.t_in,
                    b: r.t_out,
                    y: metric(r),
                })
                .collect()
        })
        .collect()
}

/// Regression design for the paper's bilinear model: rows of
/// [τ_in, τ_out, τ_in·τ_out] plus the response vector.
pub fn regression_design<F: Fn(&Row) -> f64>(
    rows: &[Row],
    metric: F,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let x = rows
        .iter()
        .map(|r| {
            let ti = r.t_in as f64;
            let to = r.t_out as f64;
            vec![ti, to, ti * to]
        })
        .collect();
    let y = rows.iter().map(|r| metric(r)).collect();
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_rows() -> Vec<Row> {
        vec![
            Row {
                model_id: "llama2-7b".into(),
                t_in: 8,
                t_out: 32,
                batch: 32,
                trial: 0,
                runtime_s: 1.25,
                gpu_energy_j: 300.5,
                cpu_energy_j: 12.75,
            },
            Row {
                model_id: "mixtral-8x7b".into(),
                t_in: 2048,
                t_out: 8,
                batch: 32,
                trial: 4,
                runtime_s: 9.5,
                gpu_energy_j: 8000.0,
                cpu_energy_j: 150.0,
            },
        ]
    }

    #[test]
    fn csv_roundtrip() {
        let rows = sample_rows();
        let csv = to_csv(&rows);
        let back = from_csv(&csv).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].model_id, "llama2-7b");
        assert!((back[0].runtime_s - 1.25).abs() < 1e-12);
        assert_eq!(back[1].t_in, 2048);
    }

    #[test]
    fn csv_rejects_bad_header() {
        assert!(from_csv("nope\n1,2,3").is_err());
        assert!(from_csv("").is_err());
    }

    #[test]
    fn csv_rejects_short_line() {
        let text = format!("{HEADER}\na,1,2\n");
        assert!(from_csv(&text).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("ecoserve_test_dataset");
        let path = dir.join("rows.csv");
        let rows = sample_rows();
        save(&rows, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), rows.len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn design_matrix_shape() {
        let rows = sample_rows();
        let (x, y) = regression_design(&rows, |r| r.total_energy_j());
        assert_eq!(x.len(), 2);
        assert_eq!(x[0], vec![8.0, 32.0, 256.0]);
        assert!((y[0] - 313.25).abs() < 1e-9);
    }

    #[test]
    fn anova_projection() {
        let rows = sample_rows();
        let obs = anova_obs(&rows, |r| r.runtime_s);
        assert_eq!(obs.len(), 2);
        assert_eq!(obs[0].a, 8);
        assert_eq!(obs[0].b, 32);
    }
}
