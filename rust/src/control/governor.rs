//! Carbon-aware ζ governance and realized-carbon accounting on the
//! simulated clock.
//!
//! Two cooperating pieces, deliberately split:
//!
//! * [`CarbonGovernor`] — the *decision* side. Owned by the replanning
//!   policy, it maps simulated time onto the grid's carbon window and
//!   steps the operational ζ through
//!   [`ZetaController`](crate::scheduler::ZetaController) once per window
//!   (plus a bounded bias from the
//!   [`PatternLearner`](super::PatternLearner)'s load forecast). Every ζ
//!   step is recorded into a trajectory that lands in the metrics
//!   artifact.
//! * [`CarbonMeter`] — the *accounting* side. Owned by the simulator
//!   itself, so realized grams-CO₂ are attributed identically for every
//!   policy under comparison: each completed query's predicted energy is
//!   converted at the grid intensity interpolated at its completion
//!   instant, and folded into per-window totals ([`CarbonWindow`]).
//!
//! Both sides read the same [`CarbonConfig`]: a diurnal
//! [`GridSignal`](crate::scheduler::GridSignal) compressed onto the
//! simulation via `day_s` (how many simulated seconds one signal day
//! spans — smoke tests use short days so a few simulated seconds sweep
//! the whole diurnal curve).

use crate::scheduler::{GridSignal, ZetaController};

/// Shared configuration of the carbon control loop.
#[derive(Debug, Clone)]
pub struct CarbonConfig {
    /// diurnal carbon-intensity curve (gCO₂/kWh), wrapping
    pub signal: GridSignal,
    /// ζ at the cleanest observed signal
    pub zeta_min: f64,
    /// ζ at the dirtiest observed signal
    pub zeta_max: f64,
    /// simulated seconds spanned by one signal day (one carbon window =
    /// `day_s / signal.hourly.len()` seconds)
    pub day_s: f64,
}

impl CarbonConfig {
    /// The stylized diurnal curve over a literal 24-hour day.
    pub fn typical(zeta_min: f64, zeta_max: f64) -> CarbonConfig {
        CarbonConfig {
            signal: GridSignal::typical_day(),
            zeta_min,
            zeta_max,
            day_s: 86_400.0,
        }
    }

    /// Simulated seconds per carbon window (one signal entry).
    pub fn window_s(&self) -> f64 {
        self.day_s / self.signal.hourly.len() as f64
    }

    /// Simulated nanoseconds → signal hours (fractional; the signal wraps).
    pub fn t_hours(&self, t_ns: u64) -> f64 {
        (t_ns as f64 / 1e9) / self.window_s()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.signal.hourly.is_empty(),
            "carbon signal needs at least one window"
        );
        anyhow::ensure!(
            self.day_s.is_finite() && self.day_s > 0.0,
            "carbon day length must be positive, got {}",
            self.day_s
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.zeta_min)
                && (0.0..=1.0).contains(&self.zeta_max)
                && self.zeta_min <= self.zeta_max,
            "carbon zeta band [{}, {}] must satisfy 0 <= min <= max <= 1",
            self.zeta_min,
            self.zeta_max
        );
        Ok(())
    }
}

/// Steps ζ once per carbon window from simulated time. The simulator's
/// event loop drives this through the policy hook on its `Timeout` /
/// `Complete` arms (and on arrivals), so ζ moves exactly when virtual
/// time crosses a window boundary — never from wall-clock reads.
#[derive(Debug, Clone)]
pub struct CarbonGovernor {
    ctl: ZetaController,
    window_s: f64,
    last_window: u64,
    zeta: f64,
    /// (virtual seconds, ζ) at every step, starting at t = 0
    trajectory: Vec<(f64, f64)>,
}

impl CarbonGovernor {
    pub fn new(cfg: &CarbonConfig) -> CarbonGovernor {
        let ctl = ZetaController::new(cfg.signal.clone(), cfg.zeta_min, cfg.zeta_max);
        let zeta = ctl.zeta_at(0.0);
        CarbonGovernor {
            ctl,
            window_s: cfg.window_s(),
            last_window: 0,
            zeta,
            trajectory: vec![(0.0, zeta)],
        }
    }

    /// Current operational ζ.
    pub fn zeta(&self) -> f64 {
        self.zeta
    }

    /// Width of the ζ band (the learner's bias is expressed against it).
    pub fn span(&self) -> f64 {
        self.ctl.zeta_max - self.ctl.zeta_min
    }

    /// Every (t_s, ζ) step taken so far, starting with the initial point.
    pub fn trajectory(&self) -> &[(f64, f64)] {
        &self.trajectory
    }

    /// Advance to the carbon window containing `t_ns`. Returns the new ζ
    /// when a window boundary was crossed *and* ζ actually moved; `bias`
    /// is an absolute ζ offset (the pattern learner's pre-positioning),
    /// clamped back into the configured band.
    pub fn step(&mut self, t_ns: u64, bias: f64) -> Option<f64> {
        let w = ((t_ns as f64 / 1e9) / self.window_s).floor() as u64;
        if w == self.last_window {
            return None;
        }
        self.last_window = w;
        let base = self.ctl.zeta_at(w as f64);
        let z = (base + bias).clamp(self.ctl.zeta_min, self.ctl.zeta_max);
        if (z - self.zeta).abs() <= 1e-12 {
            return None;
        }
        self.zeta = z;
        self.trajectory.push((w as f64 * self.window_s, z));
        Some(z)
    }
}

/// Realized carbon of one accounting window (one signal entry's span).
#[derive(Debug, Clone, PartialEq)]
pub struct CarbonWindow {
    /// window ordinal from simulation start (does not wrap with the day)
    pub index: u64,
    /// window start, virtual seconds
    pub start_s: f64,
    /// signal value at the window's knot (gCO₂/kWh)
    pub intensity: f64,
    /// predicted energy completed inside the window (J)
    pub energy_j: f64,
    /// realized grams CO₂ (each completion converted at the interpolated
    /// signal of its exact completion instant)
    pub carbon_g: f64,
}

/// The carbon block of the metrics artifact: per-window accounting plus
/// the run total.
#[derive(Debug, Clone, PartialEq)]
pub struct CarbonReport {
    pub day_s: f64,
    pub total_g: f64,
    pub windows: Vec<CarbonWindow>,
}

/// Streams completions into per-window realized-carbon totals. Owned by
/// the simulator (not the policy), so every compared policy is accounted
/// under the identical signal and time mapping.
#[derive(Debug, Clone)]
pub struct CarbonMeter {
    signal: GridSignal,
    window_s: f64,
    day_s: f64,
    windows: Vec<CarbonWindow>,
    total_g: f64,
}

impl CarbonMeter {
    pub fn new(cfg: &CarbonConfig) -> CarbonMeter {
        CarbonMeter {
            signal: cfg.signal.clone(),
            window_s: cfg.window_s(),
            day_s: cfg.day_s,
            windows: Vec::new(),
            total_g: 0.0,
        }
    }

    /// Account one completion: `energy_j` joules drawn at virtual time
    /// `t_ns`. Completions arrive in event order (non-decreasing time),
    /// so windows are appended monotonically.
    pub fn record(&mut self, t_ns: u64, energy_j: f64) {
        let t_hours = (t_ns as f64 / 1e9) / self.window_s;
        let g = energy_j / 3.6e6 * self.signal.at(t_hours);
        let index = t_hours.floor() as u64;
        let needs_new = self.windows.last().map(|w| w.index != index).unwrap_or(true);
        if needs_new {
            debug_assert!(
                self.windows.last().map(|w| w.index < index).unwrap_or(true),
                "completions must arrive in time order"
            );
            self.windows.push(CarbonWindow {
                index,
                start_s: index as f64 * self.window_s,
                intensity: self.signal.at(index as f64),
                energy_j: 0.0,
                carbon_g: 0.0,
            });
        }
        let w = self.windows.last_mut().unwrap();
        w.energy_j += energy_j;
        w.carbon_g += g;
        self.total_g += g;
    }

    pub fn report(self) -> CarbonReport {
        CarbonReport {
            day_s: self.day_s,
            total_g: self.total_g,
            windows: self.windows,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(day_s: f64) -> CarbonConfig {
        CarbonConfig {
            signal: GridSignal::typical_day(),
            zeta_min: 0.2,
            zeta_max: 0.8,
            day_s,
        }
    }

    #[test]
    fn governor_steps_only_on_window_boundaries() {
        // 24-second day: one window per simulated second.
        let mut g = CarbonGovernor::new(&cfg(24.0));
        let z0 = g.zeta();
        assert!((z0 - 0.2 - (210.0 - 190.0) / (460.0 - 190.0) * 0.6).abs() < 1e-12);
        // Inside window 0: no step.
        assert_eq!(g.step(500_000_000, 0.0), None);
        assert_eq!(g.trajectory().len(), 1);
        // Crossing into window 1 (signal 210 → 200) moves ζ down.
        let z1 = g.step(1_000_000_000, 0.0).unwrap();
        assert!(z1 < z0);
        assert_eq!(g.trajectory().len(), 2);
        assert_eq!(g.trajectory()[1].0, 1.0);
        // Re-ticking the same window is idempotent.
        assert_eq!(g.step(1_400_000_000, 0.0), None);
    }

    #[test]
    fn governor_bias_is_clamped_to_the_band() {
        let mut g = CarbonGovernor::new(&cfg(24.0));
        let z = g.step(19_000_000_000, 10.0).unwrap(); // window 19 = peak
        assert_eq!(z, 0.8);
        let z = g.step(3_000_000_000, -10.0).unwrap(); // window 3 = trough
        assert_eq!(z, 0.2);
    }

    #[test]
    fn meter_accounts_per_window_and_totals() {
        let mut m = CarbonMeter::new(&cfg(24.0));
        // 1 kWh at t = 0 (signal 210) → 210 g in window 0.
        m.record(0, 3.6e6);
        // 0.5 kWh at t = 2.5 s (signal halfway 195 → 190 = 192.5).
        m.record(2_500_000_000, 1.8e6);
        let r = m.report();
        assert_eq!(r.windows.len(), 2);
        assert_eq!(r.windows[0].index, 0);
        assert!((r.windows[0].carbon_g - 210.0).abs() < 1e-9);
        assert_eq!(r.windows[1].index, 2);
        assert!((r.windows[1].intensity - 195.0).abs() < 1e-9);
        assert!((r.windows[1].carbon_g - 0.5 * 192.5).abs() < 1e-9);
        assert!((r.total_g - (210.0 + 0.5 * 192.5)).abs() < 1e-9);
    }

    #[test]
    fn meter_windows_do_not_wrap_with_the_day() {
        let mut m = CarbonMeter::new(&cfg(24.0));
        // t = 25 s on a 24-second day: window 25, intensity wraps to hour 1.
        m.record(25_000_000_000, 3.6e6);
        let r = m.report();
        assert_eq!(r.windows[0].index, 25);
        assert!((r.windows[0].intensity - 200.0).abs() < 1e-9);
        assert_eq!(r.windows[0].start_s, 25.0);
    }

    #[test]
    fn config_validation_rejects_bad_bands_and_days() {
        assert!(cfg(24.0).validate().is_ok());
        assert!(cfg(0.0).validate().is_err());
        assert!(cfg(f64::NAN).validate().is_err());
        let mut bad = cfg(24.0);
        bad.zeta_min = 0.9;
        bad.zeta_max = 0.1;
        assert!(bad.validate().is_err());
        let mut empty = cfg(24.0);
        empty.signal.hourly.clear();
        assert!(empty.validate().is_err());
    }
}
