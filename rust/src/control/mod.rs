//! `ecoserve::control` — the online control plane: closed-loop
//! replanning and carbon-aware ζ scheduling *inside* the simulated clock.
//!
//! The paper's framework is offline — solve once, serve the plan (Eq. 2
//! over Eq. 3's capacity constraints). This module closes the loop the
//! paper's §7 outlook sketches: the same workload-based energy models
//! drive *online* decisions, deterministically, on the discrete-event
//! simulator's virtual time. Three coordinated pieces:
//!
//! * [`ReplanPolicy`] — routes from a live
//!   [`PlanSession`](crate::plan::PlanSession), re-solving via
//!   warm-started `extend` every N arrivals or early when SLO pressure
//!   (streaming queue-wait p95) crosses a threshold; between solves,
//!   queries follow the solved shape→model proportions with a
//!   largest-deficit rule.
//! * [`CarbonGovernor`] / [`CarbonMeter`] — step ζ per grid-carbon window
//!   from simulated time (warm `rezeta_shapes` repricing) and account
//!   realized grams-CO₂ per window into the metrics artifact.
//! * [`PatternLearner`] — an EWMA arrival-regime detector
//!   (burst/trough/steady) that pre-positions ζ ahead of predicted load
//!   rather than reacting to it.
//!
//! Everything here is deterministic: same (seed, arrival process, config)
//! ⇒ byte-identical metrics artifacts, CI-gated like the rest of `sim`.

pub mod governor;
pub mod pattern;
pub mod replan;

pub use governor::{CarbonConfig, CarbonGovernor, CarbonMeter, CarbonReport, CarbonWindow};
pub use pattern::{PatternLearner, Regime};
pub use replan::{ControlConfig, ReplanPolicy, ReplanStats};
