//! Online arrival-regime detection: EWMA rate tracking over fixed
//! virtual-time windows, classified into coarse regimes so the
//! [`CarbonGovernor`](super::CarbonGovernor) can *pre-position* ζ ahead
//! of predicted load instead of reacting after queues build.
//!
//! The learner is deliberately tiny and deterministic: two exponential
//! moving averages of the per-window arrival rate — a fast one (recent
//! load) and a slow one (diurnal baseline) — whose ratio is the "load
//! pressure". Pressure well above 1 means a burst is forming (the fast
//! average has outrun the baseline); well below 1 means a trough. The
//! classification feeds a bounded ζ bias: bursts push ζ up (shed energy
//! before queues grow), troughs let ζ relax toward the carbon-optimal
//! setting.

/// Coarse arrival regime over the most recent windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// not enough windows folded to classify
    Warmup,
    /// fast and slow rates agree
    Steady,
    /// recent rate well above the baseline
    Burst,
    /// recent rate well below the baseline
    Trough,
}

/// EWMA smoothing of the fast (recent) rate estimate.
const ALPHA_FAST: f64 = 0.5;
/// EWMA smoothing of the slow (baseline) rate estimate.
const ALPHA_SLOW: f64 = 0.1;
/// Pressure above this → [`Regime::Burst`].
const BURST_PRESSURE: f64 = 1.5;
/// Pressure below this → [`Regime::Trough`].
const TROUGH_PRESSURE: f64 = 1.0 / BURST_PRESSURE;
/// Windows folded before the learner leaves [`Regime::Warmup`].
const WARMUP_WINDOWS: u64 = 3;

/// Streaming arrival-pattern detector on the simulated clock.
#[derive(Debug, Clone)]
pub struct PatternLearner {
    window_s: f64,
    cur_window: u64,
    cur_count: u64,
    ewma_fast: f64,
    ewma_slow: f64,
    n_windows: u64,
    regime: Regime,
}

impl PatternLearner {
    /// `window_s`: the fixed classification window in virtual seconds
    /// (the replan policy aligns it with the carbon window when carbon
    /// control is on).
    pub fn new(window_s: f64) -> PatternLearner {
        assert!(
            window_s.is_finite() && window_s > 0.0,
            "learner window must be positive"
        );
        PatternLearner {
            window_s,
            cur_window: 0,
            cur_count: 0,
            ewma_fast: 0.0,
            ewma_slow: 0.0,
            n_windows: 0,
            regime: Regime::Warmup,
        }
    }

    /// Count one arrival at virtual time `t_ns` (folds any completed
    /// windows first).
    pub fn observe(&mut self, t_ns: u64) {
        self.advance(t_ns);
        self.cur_count += 1;
    }

    /// Advance the window clock to `t_ns` without counting an arrival
    /// (driven from timeout/completion ticks so idle windows still fold).
    pub fn advance(&mut self, t_ns: u64) {
        let w = ((t_ns as f64 / 1e9) / self.window_s).floor() as u64;
        while self.cur_window < w {
            self.fold();
            self.cur_window += 1;
            self.cur_count = 0;
        }
    }

    fn fold(&mut self) {
        let rate = self.cur_count as f64 / self.window_s;
        if self.n_windows == 0 {
            self.ewma_fast = rate;
            self.ewma_slow = rate;
        } else {
            self.ewma_fast = ALPHA_FAST * rate + (1.0 - ALPHA_FAST) * self.ewma_fast;
            self.ewma_slow = ALPHA_SLOW * rate + (1.0 - ALPHA_SLOW) * self.ewma_slow;
        }
        self.n_windows += 1;
        self.regime = if self.n_windows < WARMUP_WINDOWS {
            Regime::Warmup
        } else {
            let p = self.pressure();
            if p > BURST_PRESSURE {
                Regime::Burst
            } else if p < TROUGH_PRESSURE {
                Regime::Trough
            } else {
                Regime::Steady
            }
        };
    }

    /// Fast-over-slow rate ratio (1 = recent load matches the baseline).
    pub fn pressure(&self) -> f64 {
        self.ewma_fast / self.ewma_slow.max(1e-12)
    }

    /// Current regime classification.
    pub fn regime(&self) -> Regime {
        self.regime
    }

    /// Recent arrival-rate estimate (queries per virtual second).
    pub fn rate_estimate(&self) -> f64 {
        self.ewma_fast
    }

    /// ζ pre-positioning bias for a governor band of width `span`:
    /// bursts push ζ a quarter-band up (shed energy ahead of the load),
    /// troughs a quarter-band down (spend the slack on accuracy).
    pub fn zeta_bias(&self, span: f64) -> f64 {
        match self.regime {
            Regime::Burst => 0.25 * span,
            Regime::Trough => -0.25 * span,
            Regime::Warmup | Regime::Steady => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ns(s: f64) -> u64 {
        (s * 1e9).round() as u64
    }

    /// Feed `count` arrivals spread over each of `windows` seconds.
    fn feed(l: &mut PatternLearner, start_s: f64, windows: usize, count: usize) -> f64 {
        let mut t = start_s;
        for w in 0..windows {
            for i in 0..count {
                l.observe(ns(start_s + w as f64 + i as f64 / count as f64));
            }
            t = start_s + (w + 1) as f64;
        }
        t
    }

    #[test]
    fn warmup_then_steady_on_constant_rate() {
        let mut l = PatternLearner::new(1.0);
        assert_eq!(l.regime(), Regime::Warmup);
        let t = feed(&mut l, 0.0, 6, 10);
        l.advance(ns(t + 0.5)); // fold the last full window
        assert_eq!(l.regime(), Regime::Steady);
        assert!((l.rate_estimate() - 10.0).abs() < 1.0);
        assert!((l.pressure() - 1.0).abs() < 0.05);
        assert_eq!(l.zeta_bias(0.4), 0.0);
    }

    #[test]
    fn burst_detection_and_bias() {
        let mut l = PatternLearner::new(1.0);
        let t = feed(&mut l, 0.0, 6, 5); // baseline 5/s
        let t = feed(&mut l, t, 3, 40); // burst 40/s
        l.advance(ns(t + 0.5));
        assert_eq!(l.regime(), Regime::Burst);
        assert!(l.pressure() > BURST_PRESSURE);
        assert!((l.zeta_bias(0.4) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn trough_detection_via_idle_windows() {
        let mut l = PatternLearner::new(1.0);
        let t = feed(&mut l, 0.0, 6, 20);
        // Idle gap: advancing the clock folds empty windows.
        l.advance(ns(t + 4.5));
        assert_eq!(l.regime(), Regime::Trough);
        assert!(l.pressure() < TROUGH_PRESSURE);
        assert!((l.zeta_bias(0.4) + 0.1).abs() < 1e-12);
    }

    #[test]
    fn deterministic_under_replay() {
        let times: Vec<u64> = (0..200).map(|i| ns(0.03 * i as f64)).collect();
        let run = || {
            let mut l = PatternLearner::new(1.0);
            for &t in &times {
                l.observe(t);
            }
            (l.regime(), l.pressure(), l.rate_estimate())
        };
        assert_eq!(run(), run());
    }

    #[test]
    #[should_panic]
    fn zero_window_is_rejected() {
        PatternLearner::new(0.0);
    }
}
