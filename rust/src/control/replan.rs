//! Closed-loop replanning: a serving policy that routes from a *live*
//! [`PlanSession`] instead of a frozen plan artifact.
//!
//! The loop is MPC-shaped: arrivals accumulate into a pending batch; every
//! `replan_every` arrivals — or early, when the SLO-pressure trigger fires
//! (streaming queue-wait p95 since the last replan crossing a threshold) —
//! the batch is folded into the session via warm-started
//! [`extend`](PlanSession::extend), and the refreshed shape-level flows
//! become routing *proportions*. Between solves, queries follow those
//! proportions with a largest-deficit rule (the online analogue of
//! consuming plan budget, but self-renewing), and shapes the session has
//! never solved fall back to the ζ-cost [`Router`].
//!
//! When a [`CarbonConfig`](super::CarbonConfig) is attached, a
//! [`CarbonGovernor`](super::CarbonGovernor) steps the operational ζ per
//! carbon window — warm shape-level repricing via
//! [`rezeta_shapes`](PlanSession::rezeta_shapes) — and the
//! [`PatternLearner`](super::PatternLearner) pre-positions ζ ahead of the
//! load it forecasts.
//!
//! Under failure injection ([`FailureScript`](crate::sim::FailureScript),
//! and the [`Hazard`](crate::sim::Hazard) ensembles that generate such
//! scripts per seed), the capacity hook
//! ([`on_capacity`](ReplanPolicy::on_capacity)) is the resilience seam:
//! every kill/drain/join is folded into the live session as a warm
//! [`rescale`](PlanSession::rescale), so the routing proportions track
//! the *surviving* fleet. This is what `compare_replicated`'s
//! hazard-ensemble mode scores the replan policy on against static and
//! N+k resilient plans.

use super::governor::{CarbonConfig, CarbonGovernor};
use super::pattern::PatternLearner;
use crate::coordinator::{Policy, Router};
use crate::models::{ModelSet, Normalizer};
use crate::plan::{PlanSession, Planner, SolverKind};
use crate::stats::LogHistogram;
use crate::workload::Query;

/// Minimum queue-wait samples before the SLO trigger may fire.
const SLO_MIN_SAMPLES: u64 = 8;
/// Learner window when no carbon config supplies one (virtual seconds).
const DEFAULT_LEARN_WINDOW_S: f64 = 1.0;

/// Configuration of the online control plane (`--policy replan`).
#[derive(Debug, Clone)]
pub struct ControlConfig {
    /// re-solve after this many arrivals accumulate (≥ 1)
    pub replan_every: usize,
    /// early replan when the queue-wait p95 since the last replan crosses
    /// this threshold (virtual seconds)
    pub slo_trigger_s: Option<f64>,
    /// carbon-aware ζ governance; `None` = static ζ
    pub carbon: Option<CarbonConfig>,
}

impl Default for ControlConfig {
    fn default() -> ControlConfig {
        ControlConfig {
            replan_every: 64,
            slo_trigger_s: None,
            carbon: None,
        }
    }
}

impl ControlConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.replan_every >= 1, "--replan-every must be >= 1");
        if let Some(s) = self.slo_trigger_s {
            anyhow::ensure!(
                s.is_finite() && s > 0.0,
                "--slo-trigger-ms must be positive, got {} s",
                s
            );
        }
        if let Some(c) = &self.carbon {
            c.validate()?;
        }
        Ok(())
    }
}

/// Control-plane counters reported into the metrics artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplanStats {
    /// solver invocations triggered by the arrival cadence or SLO pressure
    pub replans: u64,
    /// subset of `replans` forced by the SLO-pressure trigger
    pub slo_replans: u64,
    /// queries routed by the deficit rule over solved proportions
    pub planned_routed: u64,
    /// queries routed by the ζ-cost fallback (shape not yet solved)
    pub fallback_routed: u64,
}

/// The closed-loop policy. Deterministic: no randomness, no wall-clock —
/// every decision is a function of (arrival sequence, virtual time, seed).
pub struct ReplanPolicy {
    replan_every: usize,
    slo_trigger_s: Option<f64>,

    session: PlanSession,
    router: Router,
    governor: Option<CarbonGovernor>,
    learner: PatternLearner,
    /// operational ζ (tracks the governor when carbon control is on)
    zeta: f64,

    pending: Vec<Query>,
    /// desired per-model replica counts from capacity events not yet
    /// applied to the session (held until the session has queries, or
    /// until a failed rescale can be retried after the next extend)
    pending_counts: Option<Vec<usize>>,
    /// per-shape routing proportions from the last solve (rows align with
    /// the session's shape slots)
    targets: Vec<Vec<f64>>,
    /// queries actually routed per (shape, model) since the run started
    served: Vec<Vec<u64>>,
    total_served: Vec<u64>,

    /// queue waits observed since the last replan (SLO-pressure estimate)
    queue_hist: LogHistogram,
    stats: ReplanStats,
    n_models: usize,
}

impl ReplanPolicy {
    pub fn new(
        sets: &[ModelSet],
        norm: Normalizer,
        zeta: f64,
        seed: u64,
        cfg: &ControlConfig,
    ) -> anyhow::Result<ReplanPolicy> {
        cfg.validate()?;
        let governor = cfg.carbon.as_ref().map(CarbonGovernor::new);
        let zeta0 = governor.as_ref().map(|g| g.zeta()).unwrap_or(zeta);
        let session = Planner::new(sets)
            .zeta(zeta0)
            .solver(SolverKind::Bucketed)
            .seed(seed)
            .session(&[])?;
        let router = Router::new(sets.to_vec(), norm, zeta0, Policy::ZetaCost);
        let window_s = cfg
            .carbon
            .as_ref()
            .map(|c| c.window_s())
            .unwrap_or(DEFAULT_LEARN_WINDOW_S);
        Ok(ReplanPolicy {
            replan_every: cfg.replan_every,
            slo_trigger_s: cfg.slo_trigger_s,
            session,
            router,
            governor,
            learner: PatternLearner::new(window_s),
            zeta: zeta0,
            pending: Vec::new(),
            pending_counts: None,
            targets: Vec::new(),
            served: Vec::new(),
            total_served: Vec::new(),
            queue_hist: LogHistogram::new(),
            stats: ReplanStats::default(),
            n_models: sets.len(),
        })
    }

    /// Current operational ζ.
    pub fn zeta(&self) -> f64 {
        self.zeta
    }

    pub fn stats(&self) -> ReplanStats {
        self.stats
    }

    /// The governor's (t_s, ζ) trajectory, when carbon control is on.
    pub fn zeta_trajectory(&self) -> Option<Vec<(f64, f64)>> {
        self.governor.as_ref().map(|g| g.trajectory().to_vec())
    }

    /// Clock tick from the simulator's event loop (`Timeout`/`Complete`
    /// arms): folds learner windows and steps the carbon governor. A ζ
    /// step reprices the live session at shape level (warm) and refreshes
    /// the routing proportions.
    pub fn tick(&mut self, t_ns: u64) -> anyhow::Result<()> {
        self.learner.advance(t_ns);
        let Some(g) = self.governor.as_mut() else {
            return Ok(());
        };
        let bias = self.learner.zeta_bias(g.span());
        if let Some(z) = g.step(t_ns, bias) {
            self.zeta = z;
            self.router.zeta = z;
            if self.session.n_queries() > 0 {
                self.session
                    .rezeta_shapes(z)
                    .map_err(|e| e.context("replan: shape-level ζ reprice failed"))?;
                self.refresh_targets();
            } else {
                self.session.set_zeta(z);
            }
        }
        Ok(())
    }

    /// Completion hook: feed the realized queue wait into the SLO-pressure
    /// estimate.
    pub fn on_complete(&mut self, queue_s: f64) {
        self.queue_hist.record(queue_s);
    }

    /// Capacity-change hook: the simulator reports that `up` replicas of
    /// `model` are dispatchable (a kill/drain lost one, a join added one).
    /// The desired count is clamped to ≥ 1 — the session still has to plan
    /// the model's workload somewhere even while its fleet is dark — and
    /// applied to the live session via warm
    /// [`rescale`](PlanSession::rescale) when possible. If the session has
    /// no queries yet (or the rescale is infeasible for the current
    /// workload), the counts are held and retried after the next extend.
    pub fn on_capacity(&mut self, model: usize, up: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            model < self.n_models,
            "capacity event for model {model} but only {} are hosted",
            self.n_models
        );
        let mut counts = self
            .pending_counts
            .clone()
            .unwrap_or_else(|| self.session.replicas().counts().to_vec());
        counts[model] = up.max(1);
        self.pending_counts =
            (counts != self.session.replicas().counts()).then_some(counts);
        self.apply_replicas();
        Ok(())
    }

    /// Try to fold pending capacity changes into the live session. A
    /// failure (e.g. the shrunken fleet needs more queries than the
    /// session holds yet) keeps the counts pending; they are retried after
    /// every extend, so a growing workload eventually absorbs them.
    fn apply_replicas(&mut self) {
        let Some(desired) = self.pending_counts.clone() else {
            return;
        };
        if self.session.n_queries() == 0 {
            return;
        }
        let current = self.session.replicas().counts().to_vec();
        let diffs: Vec<usize> = (0..current.len())
            .filter(|&k| desired[k] != current[k])
            .collect();
        let res = match diffs.as_slice() {
            [k] => self.session.rescale(*k, desired[*k]),
            _ => self
                .session
                .set_replicas(&desired)
                .and_then(|()| self.session.solve_shapes().map(|_| ())),
        };
        if res.is_ok() {
            self.pending_counts = None;
            self.refresh_targets();
        }
    }

    /// Route one arrival at virtual time `t_ns`.
    pub fn route_at(&mut self, t_ns: u64, q: &Query) -> anyhow::Result<usize> {
        self.tick(t_ns)?;
        self.learner.observe(t_ns);
        self.pending.push(*q);
        let slo = self.slo_pressure();
        if self.pending.len() >= self.replan_every || slo {
            self.replan(slo)
                .map_err(|e| e.context("replan: extend over arrival batch failed"))?;
        }
        Ok(self.route_query(q))
    }

    fn slo_pressure(&self) -> bool {
        match self.slo_trigger_s {
            Some(thr) => {
                self.queue_hist.n() >= SLO_MIN_SAMPLES && self.queue_hist.quantile(0.95) > thr
            }
            None => false,
        }
    }

    fn replan(&mut self, slo: bool) -> anyhow::Result<()> {
        let batch = std::mem::take(&mut self.pending);
        self.session.set_zeta(self.zeta);
        self.session.extend(&batch)?;
        self.refresh_targets();
        self.apply_replicas();
        self.queue_hist = LogHistogram::new();
        self.stats.replans += 1;
        if slo {
            self.stats.slo_replans += 1;
        }
        Ok(())
    }

    /// Rebuild routing proportions from the session's current optimum.
    /// Shape slots are stable across extends, so served counts carry over;
    /// new shapes append zeroed rows.
    fn refresh_targets(&mut self) {
        let flows = self
            .session
            .current_flows()
            .expect("refresh_targets: session has a solution");
        let mult = &self.session.groups().multiplicity;
        self.targets = flows
            .iter()
            .zip(mult)
            .map(|(row, &m)| {
                let m = (m as f64).max(1.0);
                row.iter().map(|&f| f as f64 / m).collect()
            })
            .collect();
        self.served.resize(self.targets.len(), vec![0; self.n_models]);
        self.total_served.resize(self.targets.len(), 0);
    }

    /// Largest-deficit routing over the solved proportions: send the query
    /// where the realized mix lags the target mix the most. Ties break to
    /// the lowest model index; models with zero target proportion have
    /// non-positive deficit and only win if every proportion is zero
    /// (impossible: rows sum to 1).
    fn route_query(&mut self, q: &Query) -> usize {
        if let Some(si) = self.session.shape_slot(q.shape().key()) {
            if si < self.targets.len() {
                let tot = (self.total_served[si] + 1) as f64;
                let mut best = 0usize;
                let mut best_d = f64::NEG_INFINITY;
                for (k, &p) in self.targets[si].iter().enumerate() {
                    let d = p * tot - self.served[si][k] as f64;
                    if d > best_d {
                        best_d = d;
                        best = k;
                    }
                }
                self.served[si][best] += 1;
                self.total_served[si] += 1;
                self.stats.planned_routed += 1;
                return best;
            }
        }
        self.stats.fallback_routed += 1;
        self.router.route(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::workload::Query;

    fn ns(s: f64) -> u64 {
        (s * 1e9).round() as u64
    }

    fn setup(cfg: &ControlConfig) -> ReplanPolicy {
        let sets = testkit::synthetic_trio();
        let norm = Normalizer::from_workload(&sets, &queries(64));
        ReplanPolicy::new(&sets, norm, 0.5, 7, cfg).unwrap()
    }

    fn queries(n: usize) -> Vec<Query> {
        (0..n)
            .map(|i| Query {
                id: i as u32,
                t_in: 20 + (i % 5) as u32 * 10,
                t_out: 40 + (i % 3) as u32 * 25,
            })
            .collect()
    }

    #[test]
    fn replans_on_the_arrival_cadence() {
        let mut p = setup(&ControlConfig {
            replan_every: 16,
            ..ControlConfig::default()
        });
        for (i, q) in queries(48).iter().enumerate() {
            p.route_at(ns(0.01 * i as f64), q).unwrap();
        }
        assert_eq!(p.stats().replans, 3);
        assert_eq!(p.stats().slo_replans, 0);
        // Arrivals 1–15 precede the first solve (fallback); from the first
        // replan on, every known shape routes by deficit.
        assert!(p.stats().fallback_routed >= 15);
        assert!(p.stats().planned_routed >= 32);
    }

    #[test]
    fn deficit_routing_tracks_the_solved_proportions() {
        let mut p = setup(&ControlConfig {
            replan_every: 8,
            ..ControlConfig::default()
        });
        let qs = queries(200);
        for (i, q) in qs.iter().enumerate() {
            p.route_at(ns(0.01 * i as f64), q).unwrap();
        }
        // The realized per-shape mix must match the final proportions to
        // within one query per model (deficit rounding).
        for (si, row) in p.targets.iter().enumerate() {
            let tot = p.total_served[si] as f64;
            if tot == 0.0 {
                continue;
            }
            for (k, &prop) in row.iter().enumerate() {
                let realized = p.served[si][k] as f64;
                assert!(
                    (realized - prop * tot).abs() <= 1.0 + 1e-9,
                    "shape {si} model {k}: realized {realized} vs target {}",
                    prop * tot
                );
            }
        }
    }

    #[test]
    fn slo_trigger_forces_early_replans() {
        let mut p = setup(&ControlConfig {
            replan_every: 1_000_000, // cadence never fires
            slo_trigger_s: Some(0.05),
            ..ControlConfig::default()
        });
        let qs = queries(40);
        for (i, q) in qs.iter().enumerate() {
            // Report queue waits well over the 50 ms trigger.
            p.on_complete(0.5);
            p.route_at(ns(0.01 * i as f64), q).unwrap();
        }
        assert!(p.stats().replans >= 1);
        assert_eq!(p.stats().replans, p.stats().slo_replans);
    }

    #[test]
    fn governor_steps_zeta_and_records_a_trajectory() {
        let mut p = setup(&ControlConfig {
            replan_every: 8,
            carbon: Some(CarbonConfig {
                day_s: 24.0, // one carbon window per simulated second
                ..CarbonConfig::typical(0.1, 0.9)
            }),
            ..ControlConfig::default()
        });
        let qs = queries(120);
        for (i, q) in qs.iter().enumerate() {
            p.route_at(ns(0.05 * i as f64), q).unwrap(); // spans ~6 windows
        }
        let traj = p.zeta_trajectory().unwrap();
        assert!(traj.len() >= 2, "expected ζ to move, got {traj:?}");
        assert!(traj.windows(2).all(|w| w[0].0 < w[1].0));
        for &(_, z) in &traj {
            assert!((0.1..=0.9).contains(&z));
        }
        // Session ζ follows the governor.
        assert!((p.session.zeta() - p.zeta()).abs() < 1e-12);
    }

    #[test]
    fn determinism_under_replay() {
        let run = || {
            let mut p = setup(&ControlConfig {
                replan_every: 8,
                slo_trigger_s: Some(0.05),
                carbon: Some(CarbonConfig {
                    day_s: 24.0,
                    ..CarbonConfig::typical(0.2, 0.8)
                }),
            });
            let mut routes = Vec::new();
            for (i, q) in queries(100).iter().enumerate() {
                if i % 3 == 0 {
                    p.on_complete(0.02 * (i % 7) as f64 + 1e-4);
                }
                routes.push(p.route_at(ns(0.02 * i as f64), q).unwrap());
            }
            (routes, p.stats(), p.zeta_trajectory())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn capacity_events_rescale_the_live_session() {
        let mut p = setup(&ControlConfig {
            replan_every: 8,
            ..ControlConfig::default()
        });
        // Before the session holds queries the change is held pending.
        p.on_capacity(0, 2).unwrap();
        assert_eq!(p.session.replicas().counts(), &[1, 1, 1]);
        for (i, q) in queries(32).iter().enumerate() {
            p.route_at(ns(0.01 * i as f64), q).unwrap();
        }
        // A replan has since folded the pending counts into the session.
        assert_eq!(p.session.replicas().counts(), &[2, 1, 1]);
        // With a live workload the change applies immediately (warm
        // rescale: exactly one model differs).
        p.on_capacity(1, 3).unwrap();
        assert_eq!(p.session.replicas().counts(), &[2, 3, 1]);
        // Losing every replica still plans the model somewhere: count
        // clamps to >= 1.
        p.on_capacity(2, 0).unwrap();
        assert_eq!(p.session.replicas().counts(), &[2, 3, 1]);
        // Out-of-range models are a hard error, not a silent no-op.
        assert!(p.on_capacity(9, 1).is_err());
    }

    #[test]
    fn capacity_churn_stays_deterministic_and_feasible() {
        // The hazard-ensemble access pattern: replicas of one model flap
        // repeatedly (kill → join → kill …) while arrivals keep flowing.
        // Every flap must fold into the session (or be held pending)
        // without wedging routing, and the whole run must replay exactly.
        let run = || {
            let mut p = setup(&ControlConfig {
                replan_every: 8,
                ..ControlConfig::default()
            });
            let qs = queries(96);
            let mut routes = Vec::new();
            for (i, q) in qs.iter().enumerate() {
                match i {
                    10 => p.on_capacity(0, 2).unwrap(), // join
                    20 => p.on_capacity(0, 1).unwrap(), // kill
                    30 => p.on_capacity(0, 2).unwrap(), // join again
                    40 => p.on_capacity(1, 0).unwrap(), // total loss: clamps
                    50 => p.on_capacity(1, 1).unwrap(), // recovery
                    _ => {}
                }
                routes.push(p.route_at(ns(0.01 * i as f64), q).unwrap());
            }
            (routes, p.session.replicas().counts().to_vec(), p.stats())
        };
        let (routes, counts, _) = run();
        assert_eq!(counts, vec![2, 1, 1]);
        // Every model index stays in range throughout the churn.
        assert!(routes.iter().all(|&k| k < 3));
        assert_eq!(run().0, routes);
    }

    #[test]
    fn config_validation() {
        assert!(ControlConfig::default().validate().is_ok());
        assert!(ControlConfig {
            replan_every: 0,
            ..ControlConfig::default()
        }
        .validate()
        .is_err());
        assert!(ControlConfig {
            slo_trigger_s: Some(0.0),
            ..ControlConfig::default()
        }
        .validate()
        .is_err());
    }
}
