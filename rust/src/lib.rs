//! # ecoserve — Offline Energy-Optimal LLM Serving
//!
//! A reproduction of *"Offline Energy-Optimal LLM Serving: Workload-Based
//! Energy Models for LLM Inference on Heterogeneous Systems"* (Wilkins,
//! Keshav, Mortier — HotCarbon'24) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L3 (this crate)** — the coordination contribution: workload
//!   characterization campaign, workload-based energy/runtime model fitting,
//!   the ζ-weighted offline assignment optimizer behind the [`plan`]
//!   facade (`Planner` → `PlanSession` → serializable `Plan` artifacts),
//!   an online serving runtime (router → batcher → per-model workers)
//!   that executes AOT-compiled model artifacts through PJRT, and a
//!   deterministic discrete-event serving simulator ([`sim`]) that
//!   replays plans under stochastic arrival processes. Python never runs
//!   on the request path.
//! * **L2 (python/compile/model.py)** — proxy LLM zoo in JAX (dense and
//!   sparse-MoE decoders), lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (decode attention,
//!   router cost matrix) called from L2 and verified against pure-jnp
//!   oracles.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod characterize;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod hardware;
pub mod models;
pub mod perfmodel;
pub mod plan;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod stats;
pub mod telemetry;
pub mod testkit;
pub mod util;
pub mod workload;
