//! `ecoserve` — CLI for the offline energy-optimal LLM serving
//! reproduction. Subcommands map one-to-one onto the paper's artifacts:
//!
//! ```text
//! ecoserve zoo                         Table 1
//! ecoserve characterize --sweep input  Fig. 1 series (output: Fig. 2)
//! ecoserve anova                       Table 2
//! ecoserve fit                         Table 3 (+ fitted coefficients)
//! ecoserve sweep-zeta                  Fig. 3 (scheduler + baselines)
//! ecoserve route --zeta 0.5            one offline assignment, counts
//! ecoserve serve                       end-to-end PJRT serving demo
//! ecoserve repro-all --out results     everything above, as CSV/MD files
//! ```

use ecoserve::characterize::{self, Campaign};
use ecoserve::config::{
    llama_family, lookup, swing_node, ExperimentConfig, LlmSpec, Partition,
};
use ecoserve::coordinator::{Policy, Request, Router, ServeConfig};
use ecoserve::hardware::Node;
use ecoserve::models::Normalizer;
use ecoserve::perfmodel::Cluster;
use ecoserve::report;
use ecoserve::scheduler::{self, CapacityMode, CostMatrix};
use ecoserve::stats;
use ecoserve::util::{logging, Args, Rng};
use ecoserve::workload::{self, Query};
use std::path::{Path, PathBuf};
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    if args.flag("quiet") {
        logging::set_level(logging::Level::Quiet);
    } else if args.flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn models_arg(args: &Args) -> anyhow::Result<Vec<LlmSpec>> {
    let ids = args.opt_list("models");
    if ids.is_empty() {
        return Ok(ecoserve::config::zoo());
    }
    ids.iter()
        .map(|id| lookup(id).ok_or_else(|| anyhow::anyhow!("unknown model '{id}'")))
        .collect()
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.command.as_deref() {
        Some("zoo") => cmd_zoo(),
        Some("characterize") => cmd_characterize(args),
        Some("anova") => cmd_anova(args),
        Some("fit") => cmd_fit(args),
        Some("sweep-zeta") => cmd_sweep_zeta(args),
        Some("route") => cmd_route(args),
        Some("serve") => cmd_serve(args),
        Some("repro-all") => cmd_repro_all(args),
        Some(other) => anyhow::bail!("unknown command '{other}' (run with no args for help)"),
        None => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
ecoserve — offline energy-optimal LLM serving (HotCarbon'24 reproduction)

USAGE: ecoserve <command> [options]

COMMANDS
  zoo                       print Table 1 (the hosted model zoo)
  characterize              run the §5 sweeps   [--sweep input|output]
                            [--models a,b] [--seed N] [--out DIR]
  anova                     Table 2: two-way ANOVA over the token grid
  fit                       Table 3: OLS fits of e_K and r_K per model
  sweep-zeta                Fig. 3: ζ sweep vs baselines
                            [--points N] [--queries N] [--gamma-caps]
  route                     solve one assignment [--zeta X] [--queries N]
  serve                     end-to-end PJRT serving demo
                            [--artifacts DIR] [--requests N] [--zeta X]
  repro-all                 regenerate every table and figure [--out DIR]

GLOBAL  --seed N   --quiet   --verbose
";

fn cmd_zoo() -> anyhow::Result<()> {
    println!("{}", report::table1(&ecoserve::config::zoo()).to_ascii());
    Ok(())
}

fn cmd_characterize(args: &Args) -> anyhow::Result<()> {
    let sweep = args.opt_or("sweep", "input");
    let specs = models_arg(args)?;
    let seed = args.opt_u64("seed", 42);
    let out_dir = args.opt_or("out", "results");
    let cfg = ExperimentConfig::default();
    let campaign = Campaign::new(Cluster::new(Node::new(swing_node())), cfg);
    let mut rng = Rng::new(seed);

    let mut by_model = Vec::new();
    for spec in &specs {
        ecoserve::info!("sweep {} for {}", sweep, spec.id);
        let cells = match sweep.as_str() {
            "input" => campaign.sweep_input(spec, &mut rng),
            "output" => campaign.sweep_output(spec, &mut rng),
            other => anyhow::bail!("--sweep must be input|output, got {other}"),
        };
        by_model.push((spec.id.to_string(), cells));
    }
    let axis = if sweep == "input" { "t_in" } else { "t_out" };
    print!("{}", report::sweep_ascii(&by_model, axis));
    let fig = if sweep == "input" { "fig1" } else { "fig2" };
    report::write_result(
        &Path::new(&out_dir).join(format!("{fig}_{sweep}_sweep.csv")),
        &report::sweep_csv(&by_model, axis),
    )?;
    Ok(())
}

/// Shared: grid rows + fitted model sets for the requested models.
fn grid_rows(
    specs: &[LlmSpec],
    trials: usize,
    seed: u64,
) -> anyhow::Result<characterize::PipelineOutput> {
    let cfg = ExperimentConfig::default();
    let mut rng = Rng::new(seed);
    characterize::characterize_and_fit(specs, &cfg, trials, &mut rng)
}

fn cmd_anova(args: &Args) -> anyhow::Result<()> {
    let specs = models_arg(args)?;
    let seed = args.opt_u64("seed", 42);
    let trials = args.opt_usize("trials", 3);
    let out = grid_rows(&specs, trials, seed)?;

    let energy_obs = characterize::anova_blocks(&out.rows, |r| r.total_energy_j());
    let runtime_obs = characterize::anova_blocks(&out.rows, |r| r.runtime_s);
    let energy = stats::two_way_blocked(&energy_obs, "Input Tokens", "Output Tokens")?;
    let runtime = stats::two_way_blocked(&runtime_obs, "Input Tokens", "Output Tokens")?;
    println!("{}", report::table2(&energy, &runtime).to_ascii());

    let out_dir = args.opt_or("out", "results");
    report::write_result(
        &Path::new(&out_dir).join("table2_anova.csv"),
        &report::table2(&energy, &runtime).to_csv(),
    )?;
    Ok(())
}

fn cmd_fit(args: &Args) -> anyhow::Result<()> {
    let specs = models_arg(args)?;
    let seed = args.opt_u64("seed", 42);
    let trials = args.opt_usize("trials", 3);
    let out = grid_rows(&specs, trials, seed)?;
    println!("{}", report::table3(&out.sets, &specs).to_ascii());
    println!("{}", report::coefficients(&out.sets).to_ascii());

    let out_dir = args.opt_or("out", "results");
    report::write_result(
        &Path::new(&out_dir).join("table3_fits.csv"),
        &report::table3(&out.sets, &specs).to_csv(),
    )?;
    Ok(())
}

fn case_study_queries(n: usize, rng: &mut Rng) -> Vec<Query> {
    workload::generate(n, &workload::AlpacaParams::default(), rng)
}

fn cmd_sweep_zeta(args: &Args) -> anyhow::Result<()> {
    let seed = args.opt_u64("seed", 42);
    let n_points = args.opt_usize("points", 11);
    let n_queries = args.opt_usize("queries", 500);
    let mode = if args.flag("gamma-caps") {
        CapacityMode::GammaHard
    } else {
        CapacityMode::Eq3Only
    };
    let partition = Partition::paper_case_study();
    partition.validate()?;

    let family = llama_family();
    let fitted = characterize::quick_fit(&family, seed)?;
    let mut rng = Rng::new(seed ^ 0xF16_3);
    let queries = case_study_queries(n_queries, &mut rng);
    let sweep = scheduler::sweep_mode(
        &fitted.sets,
        &queries,
        &partition.gammas,
        n_points,
        mode,
        &mut rng,
    )?;
    print!("{}", report::zeta_ascii(&sweep));

    let out_dir = args.opt_or("out", "results");
    report::write_result(
        &Path::new(&out_dir).join("fig3_zeta_sweep.csv"),
        &report::zeta_csv(&sweep),
    )?;
    Ok(())
}

fn cmd_route(args: &Args) -> anyhow::Result<()> {
    let seed = args.opt_u64("seed", 42);
    let zeta = args.opt_f64("zeta", 0.5);
    let n_queries = args.opt_usize("queries", 500);
    let partition = Partition::paper_case_study();
    let family = llama_family();
    let fitted = characterize::quick_fit(&family, seed)?;
    let mut rng = Rng::new(seed ^ 0xA0_77E);
    let queries = case_study_queries(n_queries, &mut rng);

    let norm = Normalizer::from_workload(&fitted.sets, &queries);
    let costs = CostMatrix::build(&fitted.sets, &norm, &queries, zeta);
    let t0 = Instant::now();
    let assignment =
        scheduler::solve_exact_mode(&costs, &partition.gammas, CapacityMode::Eq3Only)?;
    let solve_time = t0.elapsed();
    let eval = scheduler::evaluate(&assignment, &fitted.sets, &queries);

    println!("zeta = {zeta}, {n_queries} queries, solved in {solve_time:?}");
    let counts = assignment.counts(fitted.sets.len());
    for (k, s) in fitted.sets.iter().enumerate() {
        println!("  {:<12} {:>4} queries", s.model_id, counts[k]);
    }
    println!(
        "  mean energy {:.1} J | mean runtime {:.3} s | mean accuracy {:.2}%",
        eval.mean_energy_j, eval.mean_runtime_s, eval.mean_accuracy
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let n_requests = args.opt_usize("requests", 24);
    let zeta = args.opt_f64("zeta", 0.5);
    let seed = args.opt_u64("seed", 42);

    let family = llama_family();
    let fitted = characterize::quick_fit(&family, seed)?;
    let mut rng = Rng::new(seed ^ 0x5E7);

    // Proxy-scale request stream (prompts fit the artifact prompt window).
    let requests: Vec<(Request, Query)> = (0..n_requests as u64)
        .map(|id| {
            let t_in = rng.int_range(2, 48) as usize;
            let n_gen = rng.int_range(1, 16) as usize;
            let prompt: Vec<i32> = (0..t_in).map(|_| rng.int_range(1, 500) as i32).collect();
            (
                Request {
                    id,
                    prompt,
                    n_gen,
                    submitted: Instant::now(),
                },
                Query {
                    id: id as u32,
                    t_in: t_in as u32,
                    t_out: n_gen as u32,
                },
            )
        })
        .collect();

    let probe: Vec<Query> = requests.iter().map(|(_, q)| *q).collect();
    let norm = Normalizer::from_workload(&fitted.sets, &probe);
    let partition = Partition::paper_case_study();
    let router = Router::new(fitted.sets.clone(), norm, zeta, Policy::ZetaCost)
        .with_quota(&partition.gammas, 0.10);

    let ids: Vec<&str> = family.iter().map(|m| m.id).collect();
    let cfg = ServeConfig::new(artifacts, &ids);
    ecoserve::info!("compiling {} engines…", ids.len());
    let (responses, metrics) = ecoserve::coordinator::serve(&cfg, router, requests)?;
    println!("{}", metrics.report());
    println!(
        "first response tokens: {:?}",
        responses.first().map(|r| &r.tokens)
    );
    Ok(())
}

fn cmd_repro_all(args: &Args) -> anyhow::Result<()> {
    let out_dir = PathBuf::from(args.opt_or("out", "results"));
    let seed = args.opt_u64("seed", 42);
    let specs = ecoserve::config::zoo();

    // T1
    report::write_result(
        &out_dir.join("table1_zoo.csv"),
        &report::table1(&specs).to_csv(),
    )?;
    println!("{}", report::table1(&specs).to_ascii());

    // F1 + F2
    let cfg = ExperimentConfig::default();
    let campaign = Campaign::new(Cluster::new(Node::new(swing_node())), cfg);
    let mut rng = Rng::new(seed);
    let mut fig1 = Vec::new();
    let mut fig2 = Vec::new();
    for spec in &specs {
        ecoserve::info!("sweeps for {}", spec.id);
        fig1.push((spec.id.to_string(), campaign.sweep_input(spec, &mut rng)));
        fig2.push((spec.id.to_string(), campaign.sweep_output(spec, &mut rng)));
    }
    report::write_result(
        &out_dir.join("fig1_input_sweep.csv"),
        &report::sweep_csv(&fig1, "t_in"),
    )?;
    report::write_result(
        &out_dir.join("fig2_output_sweep.csv"),
        &report::sweep_csv(&fig2, "t_out"),
    )?;
    print!("{}", report::sweep_ascii(&fig1, "t_in"));

    // Grid → T2 + T3
    let pipeline = grid_rows(&specs, 3, seed)?;
    characterize::save(&pipeline.rows, &out_dir.join("grid_trials.csv"))?;
    let energy_obs = characterize::anova_blocks(&pipeline.rows, |r| r.total_energy_j());
    let runtime_obs = characterize::anova_blocks(&pipeline.rows, |r| r.runtime_s);
    let energy = stats::two_way_blocked(&energy_obs, "Input Tokens", "Output Tokens")?;
    let runtime = stats::two_way_blocked(&runtime_obs, "Input Tokens", "Output Tokens")?;
    println!("{}", report::table2(&energy, &runtime).to_ascii());
    report::write_result(
        &out_dir.join("table2_anova.csv"),
        &report::table2(&energy, &runtime).to_csv(),
    )?;
    println!("{}", report::table3(&pipeline.sets, &specs).to_ascii());
    report::write_result(
        &out_dir.join("table3_fits.csv"),
        &report::table3(&pipeline.sets, &specs).to_csv(),
    )?;
    report::write_result(
        &out_dir.join("fitted_coefficients.csv"),
        &report::coefficients(&pipeline.sets).to_csv(),
    )?;

    // F3 (case-study family, reusing the full-zoo fits)
    let family = llama_family();
    let family_sets: Vec<_> = pipeline
        .sets
        .iter()
        .filter(|s| family.iter().any(|m| m.id == s.model_id))
        .cloned()
        .collect();
    let partition = Partition::paper_case_study();
    let mut rng = Rng::new(seed ^ 0xF16_3);
    let queries = case_study_queries(500, &mut rng);
    let sweep = scheduler::sweep_mode(
        &family_sets,
        &queries,
        &partition.gammas,
        11,
        CapacityMode::Eq3Only,
        &mut rng,
    )?;
    print!("{}", report::zeta_ascii(&sweep));
    report::write_result(&out_dir.join("fig3_zeta_sweep.csv"), &report::zeta_csv(&sweep))?;

    println!(
        "\nall tables and figures regenerated under {}",
        out_dir.display()
    );
    Ok(())
}
