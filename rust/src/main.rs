//! `ecoserve` — CLI for the offline energy-optimal LLM serving
//! reproduction. Subcommands map one-to-one onto the paper's artifacts:
//!
//! ```text
//! ecoserve zoo                         Table 1
//! ecoserve characterize --sweep input  Fig. 1 series (output: Fig. 2)
//! ecoserve anova                       Table 2
//! ecoserve fit                         Table 3 (+ fitted coefficients)
//! ecoserve sweep-zeta                  Fig. 3 (scheduler + baselines)
//! ecoserve plan --out plan.json        solve offline, save the Plan artifact
//! ecoserve route --zeta 0.5            one offline assignment, counts
//! ecoserve route --plan plan.json      apply a saved Plan to the workload
//! ecoserve serve --plan plan.json      serving demo fed by the offline Plan
//! ecoserve simulate --plan plan.json   replay the plan under timed arrivals
//! ecoserve repro-all --out results     everything above, as CSV/MD files
//! ```

use ecoserve::characterize::{self, Campaign};
use ecoserve::config::{
    llama_family, lookup, swing_node, ExperimentConfig, LlmSpec, Partition,
};
use ecoserve::control::{CarbonConfig, ControlConfig};
use ecoserve::coordinator::{Policy, Request, Router, ServeConfig};
use ecoserve::hardware::Node;
use ecoserve::models::Normalizer;
use ecoserve::perfmodel::Cluster;
use ecoserve::plan::{Plan, Planner, SolverKind};
use ecoserve::report;
use ecoserve::scheduler::{self, CapacityMode, GridSignal};
use ecoserve::sim::{
    self, load_price_trace, ArrivalProcess, CompareSpec, EngineKind, FailureScript, Hazard,
    PolicyKind, ResilienceConfig, SimConfig,
};
use ecoserve::stats;
use ecoserve::util::{logging, Args, Rng};
use ecoserve::workload::{self, Query};
use std::path::{Path, PathBuf};
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    if args.flag("quiet") {
        logging::set_level(logging::Level::Quiet);
    } else if args.flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn models_arg(args: &Args) -> anyhow::Result<Vec<LlmSpec>> {
    let ids = args.opt_list("models");
    if ids.is_empty() {
        return Ok(ecoserve::config::zoo());
    }
    ids.iter()
        .map(|id| lookup(id).ok_or_else(|| anyhow::anyhow!("unknown model '{id}'")))
        .collect()
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.command.as_deref() {
        Some("zoo") => cmd_zoo(),
        Some("characterize") => cmd_characterize(args),
        Some("anova") => cmd_anova(args),
        Some("fit") => cmd_fit(args),
        Some("sweep-zeta") => cmd_sweep_zeta(args),
        Some("plan") => cmd_plan(args),
        Some("sketch") => cmd_sketch(args),
        Some("route") => cmd_route(args),
        Some("serve") => cmd_serve(args),
        Some("simulate") => cmd_simulate(args),
        Some("repro-all") => cmd_repro_all(args),
        Some(other) => anyhow::bail!("unknown command '{other}' (run with no args for help)"),
        None => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
ecoserve — offline energy-optimal LLM serving (HotCarbon'24 reproduction)

USAGE: ecoserve <command> [options]

COMMANDS
  zoo                       print Table 1 (the hosted model zoo)
  characterize              run the §5 sweeps   [--sweep input|output]
                            [--models a,b] [--seed N] [--out DIR]
  anova                     Table 2: two-way ANOVA over the token grid
  fit                       Table 3: OLS fits of e_K and r_K per model
  sweep-zeta                Fig. 3: ζ sweep vs baselines
                            [--points N] [--queries N] [--gamma-caps]
                            [--solver KIND] [--sketch]
  plan                      solve offline and save a Plan artifact
                            [--zeta X] [--queries N] [--gamma-caps]
                            [--solver bucketed|net-simplex|dense|greedy|
                             round-robin|random|single:K]
                            [--workload alpaca|serve-proxy]
                            [--requests N] [--out plan.json]
  sketch                    stream a trace into a (shape → count) sketch
                            without materializing it; optionally plan from
                            the sketch  [--trace FILE] [--lossy K] [--top K]
                            [--zeta X] [--solver bucketed|net-simplex]
                            [--gamma-caps] [--out plan.json]
  route                     solve one assignment [--zeta X] [--queries N]
                            [--solver KIND] [--gamma-caps] [--plan FILE]
                            [--workload alpaca|serve-proxy] [--requests N]
  serve                     end-to-end PJRT serving demo
                            [--artifacts DIR] [--requests N] [--zeta X]
                            [--plan FILE]
  simulate                  deterministic discrete-event serving simulation
                            [--policy plan|replan|resilient|greedy|
                             round-robin|random|compare]
                            [--engine lockstep|continuous]
                            [--plan FILE] [--arrival poisson:R|gamma:R:CV2|
                             trace] [--trace FILE] [--queries N] [--zeta X]
                            [--duration S] [--max-batch N] [--max-wait-ms MS]
                            [--slo-ms MS] [--ttft-slo-ms MS] [--tpot-slo-ms MS]
                            [--seeds N] [--per-query]
                            [--replan-every N] [--slo-trigger-ms MS]
                            [--carbon] [--carbon-band MIN:MAX]
                            [--carbon-day-s S] [--carbon-trace FILE]
                            [--replicas A,B,..] [--failures FILE]
                            [--hazard mtbf:MTBF:MTTR|
                             weibull:SHAPE:SCALE:MTTR|
                             group:MTBF:MTTR:SIZE|spot:LO:HI]
                            [--hazard-seed N] [--hazard-warmup S]
                            [--spot-trace FILE]
                            [--retry-budget N] [--retry-base-ms MS]
                            [--retry-cap-ms MS] [--breaker-threshold N]
                            [--breaker-cooldown-ms MS] [--hedge-ms MS]
                            [--resilient K] [--solver bucketed|net-simplex]
                            [--out metrics.json]
  repro-all                 regenerate every table and figure [--out DIR]

GLOBAL  --seed N   --quiet   --verbose
";

fn cmd_zoo() -> anyhow::Result<()> {
    println!("{}", report::table1(&ecoserve::config::zoo()).to_ascii());
    Ok(())
}

fn cmd_characterize(args: &Args) -> anyhow::Result<()> {
    let sweep = args.opt_or("sweep", "input");
    let specs = models_arg(args)?;
    let seed = args.opt_u64("seed", 42);
    let out_dir = args.opt_or("out", "results");
    let cfg = ExperimentConfig::default();
    let campaign = Campaign::new(Cluster::new(Node::new(swing_node())), cfg);
    let mut rng = Rng::new(seed);

    let mut by_model = Vec::new();
    for spec in &specs {
        ecoserve::info!("sweep {} for {}", sweep, spec.id);
        let cells = match sweep.as_str() {
            "input" => campaign.sweep_input(spec, &mut rng),
            "output" => campaign.sweep_output(spec, &mut rng),
            other => anyhow::bail!("--sweep must be input|output, got {other}"),
        };
        by_model.push((spec.id.to_string(), cells));
    }
    let axis = if sweep == "input" { "t_in" } else { "t_out" };
    print!("{}", report::sweep_ascii(&by_model, axis));
    let fig = if sweep == "input" { "fig1" } else { "fig2" };
    report::write_result(
        &Path::new(&out_dir).join(format!("{fig}_{sweep}_sweep.csv")),
        &report::sweep_csv(&by_model, axis),
    )?;
    Ok(())
}

/// Shared: grid rows + fitted model sets for the requested models.
fn grid_rows(
    specs: &[LlmSpec],
    trials: usize,
    seed: u64,
) -> anyhow::Result<characterize::PipelineOutput> {
    let cfg = ExperimentConfig::default();
    let mut rng = Rng::new(seed);
    characterize::characterize_and_fit(specs, &cfg, trials, &mut rng)
}

fn cmd_anova(args: &Args) -> anyhow::Result<()> {
    let specs = models_arg(args)?;
    let seed = args.opt_u64("seed", 42);
    let trials = args.opt_usize("trials", 3);
    let out = grid_rows(&specs, trials, seed)?;

    let energy_obs = characterize::anova_blocks(&out.rows, |r| r.total_energy_j());
    let runtime_obs = characterize::anova_blocks(&out.rows, |r| r.runtime_s);
    let energy = stats::two_way_blocked(&energy_obs, "Input Tokens", "Output Tokens")?;
    let runtime = stats::two_way_blocked(&runtime_obs, "Input Tokens", "Output Tokens")?;
    println!("{}", report::table2(&energy, &runtime).to_ascii());

    let out_dir = args.opt_or("out", "results");
    report::write_result(
        &Path::new(&out_dir).join("table2_anova.csv"),
        &report::table2(&energy, &runtime).to_csv(),
    )?;
    Ok(())
}

fn cmd_fit(args: &Args) -> anyhow::Result<()> {
    let specs = models_arg(args)?;
    let seed = args.opt_u64("seed", 42);
    let trials = args.opt_usize("trials", 3);
    let out = grid_rows(&specs, trials, seed)?;
    println!("{}", report::table3(&out.sets, &specs).to_ascii());
    println!("{}", report::coefficients(&out.sets).to_ascii());

    let out_dir = args.opt_or("out", "results");
    report::write_result(
        &Path::new(&out_dir).join("table3_fits.csv"),
        &report::table3(&out.sets, &specs).to_csv(),
    )?;
    Ok(())
}

fn case_study_queries(n: usize, rng: &mut Rng) -> Vec<Query> {
    workload::generate(n, &workload::AlpacaParams::default(), rng)
}

fn cmd_sweep_zeta(args: &Args) -> anyhow::Result<()> {
    let seed = args.opt_u64("seed", 42);
    let n_points = args.opt_usize("points", 11);
    let n_queries = args.opt_usize("queries", 500);
    let solver = SolverKind::parse(&args.opt_or("solver", "bucketed"))?;
    let mode = capacity_mode_arg(args);
    let partition = Partition::paper_case_study();
    partition.validate()?;

    let family = llama_family();
    let fitted = characterize::quick_fit(&family, seed)?;
    let mut rng = Rng::new(seed ^ 0xF16_3);
    let queries = case_study_queries(n_queries, &mut rng);
    let sweep = if args.flag("sketch") {
        // Shape-sketch path: collapse the workload to (shape → count)
        // first and sweep shape-level. The sketch of a materialized
        // workload is exact, so this CSV is byte-identical to the
        // query-backed sweep below (property-tested in scheduler::zeta).
        let sketch = workload::ShapeSketch::from_queries(&queries);
        scheduler::sweep_sketch(
            &fitted.sets,
            &sketch,
            &partition.gammas,
            n_points,
            mode,
            solver,
            &mut rng,
        )?
    } else {
        scheduler::sweep_solver(
            &fitted.sets,
            &queries,
            &partition.gammas,
            n_points,
            mode,
            solver,
            &mut rng,
        )?
    };
    print!("{}", report::zeta_ascii(&sweep));

    let out_dir = args.opt_or("out", "results");
    report::write_result(
        &Path::new(&out_dir).join("fig3_zeta_sweep.csv"),
        &report::zeta_csv(&sweep),
    )?;
    Ok(())
}

fn capacity_mode_arg(args: &Args) -> CapacityMode {
    if args.flag("gamma-caps") {
        CapacityMode::GammaHard
    } else {
        CapacityMode::Eq3Only
    }
}

/// The workload a plan is computed over: the §6.3 Alpaca-like case study,
/// or the same proxy-scale request stream `serve` replays (so a saved plan
/// matches `serve --plan` shape-for-shape).
fn plan_workload(args: &Args, seed: u64) -> anyhow::Result<Vec<Query>> {
    match args.opt_or("workload", "alpaca").as_str() {
        "alpaca" => {
            let n_queries = args.opt_usize("queries", 500);
            let mut rng = Rng::new(seed ^ 0xA0_77E);
            Ok(case_study_queries(n_queries, &mut rng))
        }
        "serve-proxy" => {
            let n_requests = args.opt_usize("requests", 24);
            Ok(proxy_requests(n_requests, seed)
                .into_iter()
                .map(|(_, q)| q)
                .collect())
        }
        other => anyhow::bail!("--workload must be alpaca|serve-proxy, got {other}"),
    }
}

/// A plan is only applicable to the zoo it was solved for: model ids must
/// match exactly, in order.
fn check_plan_matches(plan: &Plan, sets: &[ecoserve::models::ModelSet]) -> anyhow::Result<()> {
    let plan_ids: Vec<&str> = plan.model_ids.iter().map(String::as_str).collect();
    let family_ids: Vec<&str> = sets.iter().map(|s| s.model_id.as_str()).collect();
    if plan_ids != family_ids {
        anyhow::bail!("plan models {plan_ids:?} do not match the zoo {family_ids:?}");
    }
    Ok(())
}

fn print_assignment_summary(
    sets: &[ecoserve::models::ModelSet],
    assignment: &scheduler::Assignment,
    queries: &[Query],
) {
    let eval = scheduler::evaluate(assignment, sets, queries);
    let counts = assignment.counts(sets.len());
    for (k, s) in sets.iter().enumerate() {
        println!("  {:<12} {:>6} queries", s.model_id, counts[k]);
    }
    println!(
        "  mean energy {:.1} J | mean runtime {:.3} s | mean accuracy {:.2}%",
        eval.mean_energy_j, eval.mean_runtime_s, eval.mean_accuracy
    );
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let seed = args.opt_u64("seed", 42);
    let zeta = args.opt_f64("zeta", 0.5);
    let out = PathBuf::from(args.opt_or("out", "plan.json"));
    let solver = SolverKind::parse(&args.opt_or("solver", "bucketed"))?;
    let partition = Partition::paper_case_study();
    partition.validate()?;
    let family = llama_family();
    let fitted = characterize::quick_fit(&family, seed)?;
    let queries = plan_workload(args, seed)?;

    let mut session = Planner::new(&fitted.sets)
        .partition(&partition)
        .capacity(capacity_mode_arg(args))
        .zeta(zeta)
        .solver(solver)
        .seed(seed)
        .session(&queries)?;
    let t0 = Instant::now();
    session.solve()?;
    let solve_time = t0.elapsed();
    let plan = session.plan()?;
    plan.save(&out)?;

    println!(
        "plan: {} queries ({} distinct shapes), zeta = {zeta}, solver = {}, solved in {solve_time:?}",
        plan.n_queries,
        plan.shape_flows.len(),
        plan.solver
    );
    print_assignment_summary(&fitted.sets, session.assignment().unwrap(), &queries);
    println!("  objective {:.6} → {}", plan.objective, out.display());
    Ok(())
}

/// Stream a workload into a [`workload::ShapeSketch`] — the planning path
/// for traces too large to materialize — print its footprint, and
/// optionally solve a plan straight from the sketch. For exact sketches
/// the saved plan is byte-identical to `ecoserve plan`'s on the same
/// workload.
fn cmd_sketch(args: &Args) -> anyhow::Result<()> {
    let seed = args.opt_u64("seed", 42);
    let lossy = args
        .opt("lossy")
        .map(|s| {
            s.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--lossy expects a shape count, got '{s}'"))
        })
        .transpose()?;
    let mut sketch = match lossy {
        Some(cap) => workload::ShapeSketch::lossy(cap),
        None => workload::ShapeSketch::new(),
    };

    let t0 = Instant::now();
    let n = match args.opt("trace") {
        Some(path) => sketch.ingest_trace(Path::new(path))?,
        None => {
            let queries = plan_workload(args, seed)?;
            for q in &queries {
                sketch.observe(q);
            }
            queries.len() as u64
        }
    };
    let ingest_time = t0.elapsed();
    if let Some(top) = args.opt("top") {
        let top: usize = top
            .parse()
            .map_err(|_| anyhow::anyhow!("--top expects a shape count, got '{top}'"))?;
        sketch.compact(top);
    }

    print!(
        "sketch: {n} queries → {} distinct shapes in {ingest_time:?} (~{} KiB resident)",
        sketch.n_distinct(),
        sketch.mem_bytes() / 1024
    );
    if sketch.is_exact() {
        println!(" [exact]");
    } else {
        println!(
            " [{} queries folded into the residual bucket]",
            sketch.residual_queries()
        );
    }

    if let Some(out) = args.opt("out") {
        let zeta = args.opt_f64("zeta", 0.5);
        let solver = SolverKind::parse(&args.opt_or("solver", "bucketed"))?;
        let partition = Partition::paper_case_study();
        partition.validate()?;
        let family = llama_family();
        let fitted = characterize::quick_fit(&family, seed)?;
        let mut session = Planner::new(&fitted.sets)
            .partition(&partition)
            .capacity(capacity_mode_arg(args))
            .zeta(zeta)
            .solver(solver)
            .seed(seed)
            .from_sketch(&sketch)?;
        let t1 = Instant::now();
        session.solve_shapes()?;
        let solve_time = t1.elapsed();
        let plan = session.plan()?;
        plan.save(Path::new(out))?;
        println!(
            "plan: {} queries ({} distinct shapes), zeta = {zeta}, solver = {}, solved in {solve_time:?}",
            plan.n_queries,
            plan.shape_flows.len(),
            plan.solver
        );
        println!("  objective {:.6} → {out}", plan.objective);
    }
    Ok(())
}

fn cmd_route(args: &Args) -> anyhow::Result<()> {
    let seed = args.opt_u64("seed", 42);
    let family = llama_family();
    let fitted = characterize::quick_fit(&family, seed)?;

    // Apply a saved offline plan instead of solving.
    if let Some(path) = args.opt("plan") {
        let plan = Plan::load(Path::new(path))?;
        check_plan_matches(&plan, &fitted.sets)?;
        let queries = plan_workload(args, seed)?;
        let assignment = plan.assignment_for(&queries)?;
        println!(
            "plan {}: zeta = {}, {} queries, solver = {}",
            path,
            plan.zeta,
            plan.n_queries,
            plan.solver
        );
        print_assignment_summary(&fitted.sets, &assignment, &queries);
        return Ok(());
    }

    let zeta = args.opt_f64("zeta", 0.5);
    let solver = SolverKind::parse(&args.opt_or("solver", "bucketed"))?;
    let partition = Partition::paper_case_study();
    let queries = plan_workload(args, seed)?;

    // The bucketed production path: solves at shape granularity, so large
    // --queries stay O(shapes × models) instead of O(|Q|²·K).
    let mut session = Planner::new(&fitted.sets)
        .partition(&partition)
        .capacity(capacity_mode_arg(args))
        .zeta(zeta)
        .solver(solver)
        .seed(seed)
        .session(&queries)?;
    let t0 = Instant::now();
    session.solve()?;
    let solve_time = t0.elapsed();

    println!(
        "zeta = {zeta}, {} queries ({} distinct shapes), solved in {solve_time:?}",
        queries.len(),
        session.n_shapes()
    );
    print_assignment_summary(&fitted.sets, session.assignment().unwrap(), &queries);
    Ok(())
}

/// Proxy-scale request stream (prompts fit the artifact prompt window).
/// Deterministic in `(n, seed)` so `ecoserve plan --workload serve-proxy`
/// produces a plan that matches `serve --plan` shape-for-shape.
fn proxy_requests(n: usize, seed: u64) -> Vec<(Request, Query)> {
    let mut rng = Rng::new(seed ^ 0x5E7);
    (0..n as u64)
        .map(|id| {
            let t_in = rng.int_range(2, 48) as usize;
            let n_gen = rng.int_range(1, 16) as usize;
            let prompt: Vec<i32> = (0..t_in).map(|_| rng.int_range(1, 500) as i32).collect();
            (
                Request {
                    id,
                    prompt,
                    n_gen,
                    submitted: Instant::now(),
                },
                Query {
                    id: id as u32,
                    t_in: t_in as u32,
                    t_out: n_gen as u32,
                },
            )
        })
        .collect()
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let n_requests = args.opt_usize("requests", 24);
    let zeta = args.opt_f64("zeta", 0.5);
    let seed = args.opt_u64("seed", 42);

    let family = llama_family();
    let fitted = characterize::quick_fit(&family, seed)?;

    let requests = proxy_requests(n_requests, seed);

    // Feed the offline optimum to the online router: plan-budgeted shapes
    // follow the Plan, and the fallback scores under the *plan's* ζ and
    // normalizer so online decisions stay consistent with the offline
    // optimum.
    let plan = match args.opt("plan") {
        Some(path) => {
            let plan = Plan::load(Path::new(path))?;
            check_plan_matches(&plan, &fitted.sets)?;
            if args.opt("zeta").is_some() && plan.zeta != zeta {
                eprintln!(
                    "note: --zeta {zeta} overridden by the plan's zeta {} \
                     (fallback routing follows the plan's operating point)",
                    plan.zeta
                );
            }
            ecoserve::info!("routing with offline plan {path} (zeta {})", plan.zeta);
            Some(plan)
        }
        None => None,
    };

    let (norm, zeta) = match &plan {
        Some(p) => (p.normalizer(), p.zeta),
        None => {
            let probe: Vec<Query> = requests.iter().map(|(_, q)| *q).collect();
            (Normalizer::from_workload(&fitted.sets, &probe), zeta)
        }
    };
    let partition = Partition::paper_case_study();
    let mut router = Router::new(fitted.sets.clone(), norm, zeta, Policy::ZetaCost)
        .with_quota(&partition.gammas, 0.10);
    if let Some(p) = &plan {
        router = router.with_plan(p);
    }

    let ids: Vec<&str> = family.iter().map(|m| m.id).collect();
    let cfg = ServeConfig::new(artifacts, &ids);
    ecoserve::info!("compiling {} engines…", ids.len());
    let (responses, metrics) = ecoserve::coordinator::serve(&cfg, router, requests)?;
    println!("{}", metrics.report());
    println!(
        "first response tokens: {:?}",
        responses.first().map(|r| &r.tokens)
    );
    Ok(())
}

/// Replay a timestamped workload through a routing policy (or all of
/// them) on the simulated heterogeneous cluster — the offline plan's
/// contact with queueing, batching and burstiness. `--seeds N` replicates
/// the run over N arrival draws (policies × seeds in parallel) and
/// reports cross-seed confidence intervals.
fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let seed = args.opt_u64("seed", 42);
    let family = llama_family();
    let fitted = characterize::quick_fit(&family, seed)?;
    let sets: &[ecoserve::models::ModelSet] = &fitted.sets;

    // Workload + arrival source. The default synthetic workload matches
    // `ecoserve plan`'s (same generator, same seed derivation), so a plan
    // saved there covers this stream shape-for-shape. Arrival times are
    // either replayed verbatim from the trace (fixed across seeds) or
    // sampled once per replicate seed inside the comparison harness.
    let arrival = ArrivalProcess::parse(&args.opt_or("arrival", "poisson:50"))?;
    let (queries, trace_arrivals): (Vec<Query>, Option<Vec<f64>>) = match args.opt("trace") {
        Some(path) => {
            let records = ecoserve::workload::trace::load_records(Path::new(path))?;
            let queries: Vec<Query> = records.iter().map(|r| r.query).collect();
            match arrival {
                ArrivalProcess::Trace => {
                    let times = sim::trace_times(&records)?;
                    (queries, Some(times))
                }
                _ => (queries, None),
            }
        }
        None => {
            if arrival == ArrivalProcess::Trace {
                anyhow::bail!("--arrival trace needs --trace FILE with t_arrive timestamps");
            }
            (plan_workload(args, seed)?, None)
        }
    };

    let plan = match args.opt("plan") {
        Some(path) => {
            let plan = Plan::load(Path::new(path))?;
            check_plan_matches(&plan, sets)?;
            Some(plan)
        }
        None => None,
    };
    let (norm, zeta) = match &plan {
        Some(p) => (p.normalizer(), p.zeta),
        None => (
            Normalizer::from_workload(sets, &queries),
            args.opt_f64("zeta", 0.5),
        ),
    };

    let max_batch = args.opt_usize("max-batch", 8);
    let max_wait_ms = args.opt_f64("max-wait-ms", 50.0);
    if max_batch == 0 {
        anyhow::bail!("--max-batch must be at least 1");
    }
    // Mirror Simulator::new's bounds here so bad flags get a clean error
    // instead of an assert panic.
    if !max_wait_ms.is_finite() || !(0.0..=1e12).contains(&max_wait_ms) {
        anyhow::bail!("--max-wait-ms must be finite and in [0, 1e12], got {max_wait_ms}");
    }
    let duration_s = args
        .opt("duration")
        .map(|s| {
            s.parse::<f64>()
                .ok()
                .filter(|d| d.is_finite() && *d >= 0.0)
                .ok_or_else(|| {
                    anyhow::anyhow!("--duration expects non-negative seconds, got '{s}'")
                })
        })
        .transpose()?;
    let n_seeds = args.opt_usize("seeds", 1);
    if n_seeds == 0 {
        anyhow::bail!("--seeds must be at least 1");
    }
    let slo_ms = args.opt_f64("slo-ms", 30_000.0);
    if !slo_ms.is_finite() || slo_ms < 0.0 {
        anyhow::bail!("--slo-ms must be finite and >= 0, got {slo_ms}");
    }
    // Engine selection: lockstep (batch-serial, the paper's measurement
    // protocol) or continuous (iteration-level batching, phase split).
    let engine_arg = args.opt_or("engine", "lockstep");
    let engine = EngineKind::parse(&engine_arg).ok_or_else(|| {
        anyhow::anyhow!("--engine expects lockstep|continuous, got '{engine_arg}'")
    })?;
    // Token-level SLOs (optional): TTFT/TPOT attainment is reported only
    // when the corresponding flag is set.
    let token_slo = |flag: &str| -> anyhow::Result<Option<f64>> {
        args.opt(flag)
            .map(|s| {
                s.parse::<f64>()
                    .ok()
                    .filter(|ms| ms.is_finite() && *ms > 0.0)
                    .map(|ms| ms / 1000.0)
                    .ok_or_else(|| {
                        anyhow::anyhow!("--{flag} expects positive milliseconds, got '{s}'")
                    })
            })
            .transpose()
    };
    let ttft_slo_s = token_slo("ttft-slo-ms")?;
    let tpot_slo_s = token_slo("tpot-slo-ms")?;

    // Online control plane (ecoserve::control). Always constructed so
    // `--policy replan` and `--policy compare` work without extra flags;
    // carbon metering stays off unless --carbon is passed.
    let replan_every = args.opt_usize("replan-every", 64);
    if replan_every == 0 {
        anyhow::bail!("--replan-every must be at least 1");
    }
    let slo_trigger_s = args
        .opt("slo-trigger-ms")
        .map(|s| {
            s.parse::<f64>()
                .ok()
                .filter(|ms| ms.is_finite() && *ms > 0.0)
                .map(|ms| ms / 1000.0)
                .ok_or_else(|| {
                    anyhow::anyhow!("--slo-trigger-ms expects positive milliseconds, got '{s}'")
                })
        })
        .transpose()?;
    let carbon = if args.flag("carbon") || args.opt("carbon-trace").is_some() {
        let (zeta_min, zeta_max) = match args.opt("carbon-band") {
            Some(band) => {
                let parse = |s: &str| {
                    s.parse::<f64>()
                        .ok()
                        .filter(|z| z.is_finite() && (0.0..=1.0).contains(z))
                };
                match band.split_once(':') {
                    Some((lo, hi)) => match (parse(lo), parse(hi)) {
                        (Some(lo), Some(hi)) if lo <= hi => (lo, hi),
                        _ => anyhow::bail!(
                            "--carbon-band expects MIN:MAX with 0 <= MIN <= MAX <= 1, \
                             got '{band}'"
                        ),
                    },
                    None => anyhow::bail!("--carbon-band expects MIN:MAX, got '{band}'"),
                }
            }
            // Default band floors at the static ζ: the governor only ever
            // pushes ζ up (toward energy) as the grid gets dirtier, so a
            // carbon-governed run never spends more energy than the
            // static plan it replaces.
            None => (zeta, zeta.max(0.9)),
        };
        let day_s = args.opt_f64("carbon-day-s", 86_400.0);
        if !day_s.is_finite() || day_s <= 0.0 {
            anyhow::bail!("--carbon-day-s must be finite and > 0, got {day_s}");
        }
        let mut carbon = CarbonConfig::typical(zeta_min, zeta_max);
        // A real grid-intensity trace replaces the stylized diurnal
        // curve; implies --carbon. CSV (`hour,gco2_per_kwh`) or JSONL by
        // file extension.
        if let Some(path) = args.opt("carbon-trace") {
            let text = std::fs::read_to_string(Path::new(path))
                .map_err(|e| anyhow::anyhow!("cannot read grid trace {path}: {e}"))?;
            carbon.signal = if path.ends_with(".jsonl") {
                GridSignal::from_jsonl(&text)?
            } else {
                GridSignal::from_csv(&text)?
            };
        }
        carbon.day_s = day_s;
        Some(carbon)
    } else {
        None
    };
    let control = ControlConfig {
        replan_every,
        slo_trigger_s,
        carbon,
    };

    // Elastic-cluster flags: per-model replica counts (in zoo order) and a
    // JSONL failure script injecting kill/drain/join events on the
    // virtual clock.
    let replica_counts: Option<Vec<usize>> = {
        let list = args.opt_list("replicas");
        if list.is_empty() {
            None
        } else {
            anyhow::ensure!(
                list.len() == sets.len(),
                "--replicas lists {} counts but {} models are hosted",
                list.len(),
                sets.len()
            );
            Some(
                list.iter()
                    .map(|s| {
                        s.parse::<usize>().map_err(|_| {
                            anyhow::anyhow!(
                                "--replicas expects comma-separated counts, got '{s}'"
                            )
                        })
                    })
                    .collect::<anyhow::Result<Vec<usize>>>()?,
            )
        }
    };
    let failures = args
        .opt("failures")
        .map(|path| {
            let text = std::fs::read_to_string(Path::new(path))
                .map_err(|e| anyhow::anyhow!("cannot read failure script {path}: {e}"))?;
            FailureScript::from_jsonl_with_fleet(&text, replica_counts.as_deref())
        })
        .transpose()?;

    // Stochastic outage ensembles: a hazard process (instead of a fixed
    // script) draws one failure schedule per replicate seed, shared by
    // every compared policy at that seed.
    let hazard = args
        .opt("hazard")
        .map(|spec| -> anyhow::Result<Hazard> {
            let mut h = Hazard::parse(spec)?;
            if let Some(s) = args.opt("hazard-warmup") {
                let warmup_s: f64 = s.parse().map_err(|_| {
                    anyhow::anyhow!("--hazard-warmup expects seconds, got '{s}'")
                })?;
                h = h.with_warmup(warmup_s)?;
            }
            if let Some(path) = args.opt("spot-trace") {
                let text = std::fs::read_to_string(Path::new(path))
                    .map_err(|e| anyhow::anyhow!("cannot read price trace {path}: {e}"))?;
                h = h.with_price_trace(load_price_trace(&text)?);
            }
            Ok(h)
        })
        .transpose()?;
    let hazard_seed = args.opt_u64("hazard-seed", seed);

    // Request-level survival: retry/backoff, circuit breaker, tail
    // hedging. Armed (at defaults) whenever a hazard runs, or explicitly
    // by any of its flags; absent, kills fall back to plain requeueing.
    let any_resilience_flag = [
        "retry-budget",
        "retry-base-ms",
        "retry-cap-ms",
        "breaker-threshold",
        "breaker-cooldown-ms",
        "hedge-ms",
    ]
    .iter()
    .any(|f| args.opt(f).is_some());
    let resilience = if any_resilience_flag || hazard.is_some() {
        let ms = |flag: &str, default_s: f64| -> anyhow::Result<f64> {
            match args.opt(flag) {
                None => Ok(default_s),
                Some(s) => s
                    .parse::<f64>()
                    .ok()
                    .filter(|ms| ms.is_finite() && *ms > 0.0)
                    .map(|ms| ms / 1000.0)
                    .ok_or_else(|| {
                        anyhow::anyhow!("--{flag} expects positive milliseconds, got '{s}'")
                    }),
            }
        };
        let count = |flag: &str, default: u32| -> anyhow::Result<u32> {
            match args.opt(flag) {
                None => Ok(default),
                Some(s) => s
                    .parse::<u32>()
                    .map_err(|_| anyhow::anyhow!("--{flag} expects a count, got '{s}'")),
            }
        };
        let d = ResilienceConfig::default();
        Some(ResilienceConfig {
            retry_budget: count("retry-budget", d.retry_budget)?,
            retry_base_s: ms("retry-base-ms", d.retry_base_s)?,
            retry_cap_s: ms("retry-cap-ms", d.retry_cap_s)?,
            breaker_threshold: count("breaker-threshold", d.breaker_threshold)?,
            breaker_cooldown_s: ms("breaker-cooldown-ms", d.breaker_cooldown_s)?,
            hedge_after_s: if args.opt("hedge-ms").is_some() {
                Some(ms("hedge-ms", 0.0)?)
            } else {
                None
            },
        })
    } else {
        None
    };

    // N+k resilient plan: re-solve the simulated workload with failover
    // headroom ([`PlanSession::plan_resilient`]) and hand the result to
    // the `resilient` policy.
    let resilient_k = args.opt_usize("resilient", 0);
    let resilient_plan = if resilient_k > 0 {
        let partition = Partition::paper_case_study();
        partition.validate()?;
        let solver = SolverKind::parse(&args.opt_or("solver", "bucketed"))?;
        let mut session = Planner::new(sets)
            .partition(&partition)
            .capacity(capacity_mode_arg(args))
            .zeta(zeta)
            .solver(solver)
            .seed(seed)
            .session(&queries)?;
        if let Some(counts) = &replica_counts {
            session.set_replicas(counts)?;
        }
        let p = session.plan_resilient(resilient_k)?;
        ecoserve::info!(
            "N+{resilient_k} resilient plan solved (objective {:.6})",
            p.objective
        );
        Some(p)
    } else {
        None
    };

    let cfg = SimConfig {
        max_batch,
        max_wait_s: max_wait_ms / 1000.0,
        slo_s: slo_ms / 1000.0,
        ttft_slo_s,
        tpot_slo_s,
        duration_s,
        // Exact quantiles + per-query lifecycles: O(|Q|) memory, opt-in.
        per_query: args.flag("per-query"),
        memoize: true,
        engine,
    };
    let spec = CompareSpec {
        sets,
        norm,
        zeta,
        plan: plan.as_ref(),
        seed,
        cfg,
        arrival_label: arrival.label(),
        control: Some(control),
        replicas: replica_counts.as_deref(),
        failures: failures.as_ref(),
        hazard: hazard.as_ref(),
        hazard_seed,
        resilient_plan: resilient_plan.as_ref(),
        resilience,
    };
    let arrivals_src = match &trace_arrivals {
        Some(times) => sim::Arrivals::Fixed(times),
        None => sim::Arrivals::Sampled(arrival),
    };

    let policy_arg = args.opt_or("policy", if plan.is_some() { "plan" } else { "greedy" });
    let kinds: Vec<PolicyKind> = if policy_arg == "compare" {
        // Policy-comparison harness: every policy replays the same trace.
        if plan.is_none() {
            ecoserve::info!("no --plan given: skipping the plan-following policy");
        }
        if resilient_plan.is_none() {
            ecoserve::info!("no --resilient K given: skipping the resilient policy");
        }
        PolicyKind::all()
            .into_iter()
            .filter(|&k| k != PolicyKind::Plan || plan.is_some())
            .filter(|&k| k != PolicyKind::Resilient || resilient_plan.is_some())
            .collect()
    } else {
        vec![PolicyKind::parse(&policy_arg)?]
    };
    if matches!(arrivals_src, sim::Arrivals::Fixed(_)) && n_seeds > 1 {
        ecoserve::info!(
            "trace arrivals replay fixed timestamps: --seeds {n_seeds} varies \
             only the policy randomness"
        );
    }
    let grid = sim::compare_replicated(&spec, &queries, arrivals_src, &kinds, n_seeds)?;

    if n_seeds > 1 {
        println!("{}", report::sim_comparison_replicated(&grid).to_ascii());
        if let Some(out) = args.opt("out") {
            report::write_result(
                Path::new(out),
                &sim::replicated_to_json(&grid).to_string_pretty(),
            )?;
        }
    } else if policy_arg == "compare" {
        let rows: Vec<sim::SimMetrics> =
            grid.into_iter().map(|mut runs| runs.remove(0)).collect();
        println!("{}", report::sim_comparison(&rows).to_ascii());
        if let Some(out) = args.opt("out") {
            report::write_result(
                Path::new(out),
                &sim::comparison_to_json(&rows).to_string_pretty(),
            )?;
        }
    } else {
        let m = &grid[0][0];
        println!("{}", report::sim_summary(m).to_ascii());
        println!(
            "  total energy {:.1} J | mean latency {:.3} s | p95 {:.3} s | \
             queue {:.3} s | SLO({}s) {:.1}% | makespan {:.2} s",
            m.total_energy_j,
            m.mean_latency_s,
            m.p95_latency_s,
            m.mean_queue_s,
            m.slo_s,
            100.0 * m.slo_attainment,
            m.makespan_s
        );
        print!(
            "  engine {} | TTFT p95 {:.3} s | TPOT p95 {:.4} s | prefill {:.1} J | \
             decode {:.1} J",
            m.engine, m.p95_ttft_s, m.p95_tpot_s, m.prefill_energy_j, m.decode_energy_j
        );
        if let (Some(slo), Some(att)) = (m.ttft_slo_s, m.ttft_attainment) {
            print!(" | TTFT SLO({slo}s) {:.1}%", 100.0 * att);
        }
        if let (Some(slo), Some(att)) = (m.tpot_slo_s, m.tpot_attainment) {
            print!(" | TPOT SLO({slo}s) {:.1}%", 100.0 * att);
        }
        println!();
        if let Some((followed, fallback)) = m.plan_decisions {
            println!("  plan followed {followed} queries, fallback routed {fallback}");
        }
        if let Some(rs) = m.replan_stats {
            println!(
                "  replans {} ({} SLO-triggered) | planned routed {} | fallback {}",
                rs.replans, rs.slo_replans, rs.planned_routed, rs.fallback_routed
            );
        }
        if m.n_failed > 0 || m.n_retries > 0 || m.n_hedges > 0 || m.n_breaker_trips > 0 {
            println!(
                "  availability {:.1}% | goodput {:.1} q/s | failed {} | retries {} | \
                 hedges {} | breaker trips {}",
                100.0 * m.availability,
                m.goodput_qps,
                m.n_failed,
                m.n_retries,
                m.n_hedges,
                m.n_breaker_trips
            );
        }
        if let Some(c) = &m.carbon {
            println!(
                "  realized carbon {:.2} g over {} grid window(s)",
                c.total_g,
                c.windows.len()
            );
        }
        if let Some(out) = args.opt("out") {
            report::write_result(Path::new(out), &m.to_json().to_string_pretty())?;
        }
    }
    Ok(())
}

fn cmd_repro_all(args: &Args) -> anyhow::Result<()> {
    let out_dir = PathBuf::from(args.opt_or("out", "results"));
    let seed = args.opt_u64("seed", 42);
    let specs = ecoserve::config::zoo();

    // T1
    report::write_result(
        &out_dir.join("table1_zoo.csv"),
        &report::table1(&specs).to_csv(),
    )?;
    println!("{}", report::table1(&specs).to_ascii());

    // F1 + F2
    let cfg = ExperimentConfig::default();
    let campaign = Campaign::new(Cluster::new(Node::new(swing_node())), cfg);
    let mut rng = Rng::new(seed);
    let mut fig1 = Vec::new();
    let mut fig2 = Vec::new();
    for spec in &specs {
        ecoserve::info!("sweeps for {}", spec.id);
        fig1.push((spec.id.to_string(), campaign.sweep_input(spec, &mut rng)));
        fig2.push((spec.id.to_string(), campaign.sweep_output(spec, &mut rng)));
    }
    report::write_result(
        &out_dir.join("fig1_input_sweep.csv"),
        &report::sweep_csv(&fig1, "t_in"),
    )?;
    report::write_result(
        &out_dir.join("fig2_output_sweep.csv"),
        &report::sweep_csv(&fig2, "t_out"),
    )?;
    print!("{}", report::sweep_ascii(&fig1, "t_in"));

    // Grid → T2 + T3
    let pipeline = grid_rows(&specs, 3, seed)?;
    characterize::save(&pipeline.rows, &out_dir.join("grid_trials.csv"))?;
    let energy_obs = characterize::anova_blocks(&pipeline.rows, |r| r.total_energy_j());
    let runtime_obs = characterize::anova_blocks(&pipeline.rows, |r| r.runtime_s);
    let energy = stats::two_way_blocked(&energy_obs, "Input Tokens", "Output Tokens")?;
    let runtime = stats::two_way_blocked(&runtime_obs, "Input Tokens", "Output Tokens")?;
    println!("{}", report::table2(&energy, &runtime).to_ascii());
    report::write_result(
        &out_dir.join("table2_anova.csv"),
        &report::table2(&energy, &runtime).to_csv(),
    )?;
    println!("{}", report::table3(&pipeline.sets, &specs).to_ascii());
    report::write_result(
        &out_dir.join("table3_fits.csv"),
        &report::table3(&pipeline.sets, &specs).to_csv(),
    )?;
    report::write_result(
        &out_dir.join("fitted_coefficients.csv"),
        &report::coefficients(&pipeline.sets).to_csv(),
    )?;

    // F3 (case-study family, reusing the full-zoo fits)
    let family = llama_family();
    let family_sets: Vec<_> = pipeline
        .sets
        .iter()
        .filter(|s| family.iter().any(|m| m.id == s.model_id))
        .cloned()
        .collect();
    let partition = Partition::paper_case_study();
    let mut rng = Rng::new(seed ^ 0xF16_3);
    let queries = case_study_queries(500, &mut rng);
    let sweep = scheduler::sweep_mode(
        &family_sets,
        &queries,
        &partition.gammas,
        11,
        CapacityMode::Eq3Only,
        &mut rng,
    )?;
    print!("{}", report::zeta_ascii(&sweep));
    report::write_result(&out_dir.join("fig3_zeta_sweep.csv"), &report::zeta_csv(&sweep))?;

    println!(
        "\nall tables and figures regenerated under {}",
        out_dir.display()
    );
    Ok(())
}
