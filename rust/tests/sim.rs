//! Integration properties of the discrete-event serving simulator:
//! determinism (same seed + config ⇒ byte-identical metrics JSON),
//! plan-vs-baseline energy ordering on capacity-feasible instances, and
//! trace-replay arrival fidelity.

use ecoserve::models::{AccuracyModel, ModelSet, Normalizer, Target, WorkloadModel};
use ecoserve::plan::{Plan, Planner, SolverKind};
use ecoserve::scheduler::capacity_bounds;
use ecoserve::scheduler::CapacityMode;
use ecoserve::sim::{
    compare, comparison_to_json, ArrivalProcess, CompareSpec, PolicyKind, SimConfig, SimMetrics,
    Simulator,
};
use ecoserve::testkit::{forall, Config};
use ecoserve::util::Rng;
use ecoserve::workload::Query;

/// Random paper-like model sets (same generator as tests/plan.rs).
fn random_sets(rng: &mut Rng, n_models: usize) -> Vec<ModelSet> {
    (0..n_models)
        .map(|i| {
            let scale = rng.range(0.5, 8.0);
            ModelSet {
                model_id: format!("m{i}"),
                energy: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::EnergyJ,
                    coefs: [0.5 * scale, 8.0 * scale, 0.003 * scale],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                runtime: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::RuntimeS,
                    coefs: [1e-3 * scale, 1e-2 * scale, 1e-6 * scale],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                accuracy: AccuracyModel::new(&format!("m{i}"), rng.range(40.0, 70.0)),
            }
        })
        .collect()
}

/// Workload drawn from a small shape table (heavy duplication — the
/// bucketed regime the plan budgets cover shape-for-shape).
fn shaped_workload(rng: &mut Rng, n_shapes: usize, n: usize) -> Vec<Query> {
    let table: Vec<(u32, u32)> = (0..n_shapes)
        .map(|_| {
            (
                rng.int_range(1, 1024) as u32,
                rng.int_range(1, 2048) as u32,
            )
        })
        .collect();
    (0..n)
        .map(|i| {
            let (t_in, t_out) = table[rng.index(table.len())];
            Query {
                id: i as u32,
                t_in,
                t_out,
            }
        })
        .collect()
}

fn plan_for(sets: &[ModelSet], queries: &[Query], zeta: f64, seed: u64) -> Plan {
    let mut session = Planner::new(sets)
        .capacity(CapacityMode::Eq3Only)
        .zeta(zeta)
        .solver(SolverKind::Bucketed)
        .seed(seed)
        .session(queries)
        .unwrap();
    session.solve().unwrap();
    session.plan().unwrap()
}

/// One full comparison run: every policy over the same seeded trace.
fn run_compare(seed: u64) -> (Vec<SimMetrics>, Vec<Query>, Vec<ModelSet>) {
    let mut rng = Rng::new(seed);
    let n_models = 2 + rng.index(3);
    let sets = random_sets(&mut rng, n_models);
    let n = 40 + rng.index(120);
    let queries = shaped_workload(&mut rng.fork(1), 6, n);
    let arrivals = ArrivalProcess::Poisson { rate: 40.0 }
        .times(n, &mut rng.fork(2))
        .unwrap();
    let plan = plan_for(&sets, &queries, 1.0, seed);
    let spec = CompareSpec {
        sets: &sets,
        norm: plan.normalizer(),
        zeta: 1.0,
        plan: Some(&plan),
        seed,
        cfg: SimConfig {
            max_batch: 4,
            max_wait_s: 0.02,
            slo_s: 5.0,
            duration_s: None,
        },
        arrival_label: "poisson:40".to_string(),
    };
    let rows = compare(&spec, &queries, &arrivals, &PolicyKind::all()).unwrap();
    (rows, queries, sets)
}

#[test]
fn same_seed_and_config_give_byte_identical_metrics_json() {
    forall(Config::default().cases(6), |rng| {
        let seed = rng.next_u64();
        let (a, _, _) = run_compare(seed);
        let (b, _, _) = run_compare(seed);
        let ja = comparison_to_json(&a).to_string_pretty();
        let jb = comparison_to_json(&b).to_string_pretty();
        assert_eq!(ja, jb, "seed {seed} not byte-identical");
        // And per-policy artifacts individually.
        for (ma, mb) in a.iter().zip(&b) {
            assert_eq!(
                ma.to_json().to_string_pretty(),
                mb.to_json().to_string_pretty()
            );
        }
    });
}

#[test]
fn different_seeds_change_the_trace() {
    let (a, _, _) = run_compare(101);
    let (b, _, _) = run_compare(102);
    assert_ne!(
        comparison_to_json(&a).to_string_pretty(),
        comparison_to_json(&b).to_string_pretty()
    );
}

/// At ζ = 1 the plan is the minimum-energy assignment subject to Eq. 3;
/// any query-independent baseline whose realized assignment is itself
/// Eq. 3-feasible can therefore never beat it on total energy.
#[test]
fn plan_energy_never_beaten_by_feasible_query_independent_baselines() {
    forall(Config::default().cases(10), |rng| {
        let seed = rng.next_u64();
        let (rows, queries, sets) = run_compare(seed);
        let by_label = |label: &str| rows.iter().find(|m| m.policy == label).unwrap();
        let plan_m = by_label("plan");
        // The sim replays the exact workload the plan was solved on, so
        // every query follows the plan (no fallback decisions).
        assert_eq!(plan_m.plan_decisions.unwrap().1, 0, "seed {seed}");
        assert_eq!(plan_m.n_queries, queries.len());

        let caps = capacity_bounds(
            CapacityMode::Eq3Only,
            &vec![1.0 / sets.len() as f64; sets.len()],
            queries.len(),
        );
        for label in ["round-robin", "random"] {
            let base = by_label(label);
            // Reconstruct the baseline's per-model counts from its nodes.
            let counts: Vec<u64> = base.nodes.iter().map(|nd| nd.queries).collect();
            let feasible = counts.iter().all(|&c| c >= 1)
                && counts
                    .iter()
                    .zip(&caps)
                    .all(|(&c, &cap)| c as usize <= cap);
            if !feasible {
                continue; // infeasible realizations are outside Eq. 3's space
            }
            // Headroom for COST_SCALE quantization: the solver optimizes
            // 1e-9-rounded normalized costs, so the true-energy optimum
            // can trail by up to n·1e-9·max_e — far below 0.01% of any
            // feasible baseline's total.
            let eps = 1e-4 * base.total_energy_j.abs() + 1e-3;
            assert!(
                plan_m.total_energy_j <= base.total_energy_j + eps,
                "seed {seed}: plan {} J > {label} {} J",
                plan_m.total_energy_j,
                base.total_energy_j
            );
        }
    });
}

#[test]
fn trace_replay_preserves_arrival_timestamps() {
    let mut rng = Rng::new(77);
    let sets = random_sets(&mut rng, 2);
    let queries: Vec<Query> = (0..10)
        .map(|i| Query {
            id: i,
            t_in: 32,
            t_out: 64,
        })
        .collect();
    // Deliberately unsorted timestamps: the simulator must order them.
    let arrivals: Vec<f64> = (0..10)
        .map(|i| if i % 2 == 0 { i as f64 } else { 20.0 - i as f64 })
        .collect();
    let norm = Normalizer::from_workload(&sets, &queries);
    let mut policy = ecoserve::sim::SimPolicy::new(
        PolicyKind::Greedy,
        &sets,
        norm,
        0.5,
        None,
        1,
    )
    .unwrap();
    let m = Simulator::new(&sets, SimConfig::default())
        .labeled("trace", 1, 0.5)
        .run(&queries, &arrivals, &mut policy)
        .unwrap();
    assert_eq!(m.n_queries, 10);
    let mut by_id: Vec<_> = m.outcomes.clone();
    by_id.sort_by_key(|o| o.id);
    for (o, want) in by_id.iter().zip(&arrivals) {
        assert_eq!(o.t_arrive, *want, "query {}", o.id);
        assert!(o.t_complete >= o.t_arrive);
    }
    assert_eq!(m.arrival, "trace");
}
