//! Integration properties of the discrete-event serving simulator:
//! determinism (same seed + config ⇒ byte-identical metrics JSON, single
//! and parallel `--seeds` replicated, under both engines), plan-vs-
//! baseline energy ordering on capacity-feasible instances, trace-replay
//! arrival fidelity, streaming-vs-exact quantile agreement, the
//! version-5 metrics artifact golden (byte-exact round-trip +
//! version-1 through -4 rejection), conservation and energy parity across the
//! lockstep/continuous engine switch, and the online control plane
//! (replan+carbon determinism; the carbon-governed replan's energy never
//! exceeding the static plan's on a Gamma burst).

use ecoserve::models::{AccuracyModel, ModelSet, Normalizer, Target, WorkloadModel};
use ecoserve::plan::{Plan, Planner, SolverKind};
use ecoserve::scheduler::capacity_bounds;
use ecoserve::scheduler::CapacityMode;
use ecoserve::sim::{
    compare, compare_replicated, comparison_to_json, replicated_to_json, ArrivalProcess,
    Arrivals, CompareSpec, EngineKind, PolicyKind, SimConfig, SimMetrics, Simulator,
};
use ecoserve::stats::{quantile, LOG_HIST_BINS_PER_OCTAVE};
use ecoserve::testkit::{forall, Config};
use ecoserve::util::{Json, Rng};
use ecoserve::workload::Query;

/// Random paper-like model sets (same generator as tests/plan.rs).
fn random_sets(rng: &mut Rng, n_models: usize) -> Vec<ModelSet> {
    (0..n_models)
        .map(|i| {
            let scale = rng.range(0.5, 8.0);
            ModelSet {
                model_id: format!("m{i}"),
                energy: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::EnergyJ,
                    coefs: [0.5 * scale, 8.0 * scale, 0.003 * scale],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                runtime: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::RuntimeS,
                    coefs: [1e-3 * scale, 1e-2 * scale, 1e-6 * scale],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                accuracy: AccuracyModel::new(&format!("m{i}"), rng.range(40.0, 70.0)),
            }
        })
        .collect()
}

/// Workload drawn from a small shape table (heavy duplication — the
/// bucketed regime both the plan budgets and the simulator's prediction
/// memoization cover shape-for-shape).
fn shaped_workload(rng: &mut Rng, n_shapes: usize, n: usize) -> Vec<Query> {
    let table: Vec<(u32, u32)> = (0..n_shapes)
        .map(|_| {
            (
                rng.int_range(1, 1024) as u32,
                rng.int_range(1, 2048) as u32,
            )
        })
        .collect();
    (0..n)
        .map(|i| {
            let (t_in, t_out) = table[rng.index(table.len())];
            Query {
                id: i as u32,
                t_in,
                t_out,
            }
        })
        .collect()
}

fn plan_for(sets: &[ModelSet], queries: &[Query], zeta: f64, seed: u64) -> Plan {
    let mut session = Planner::new(sets)
        .capacity(CapacityMode::Eq3Only)
        .zeta(zeta)
        .solver(SolverKind::Bucketed)
        .seed(seed)
        .session(queries)
        .unwrap();
    session.solve().unwrap();
    session.plan().unwrap()
}

/// One full comparison run: every policy over the same seeded trace.
fn run_compare(seed: u64) -> (Vec<SimMetrics>, Vec<Query>, Vec<ModelSet>) {
    let mut rng = Rng::new(seed);
    let n_models = 2 + rng.index(3);
    let sets = random_sets(&mut rng, n_models);
    let n = 40 + rng.index(120);
    let queries = shaped_workload(&mut rng.fork(1), 6, n);
    let arrivals = ArrivalProcess::Poisson { rate: 40.0 }
        .times(n, &mut rng.fork(2))
        .unwrap();
    let plan = plan_for(&sets, &queries, 1.0, seed);
    let spec = CompareSpec {
        sets: &sets,
        norm: plan.normalizer(),
        zeta: 1.0,
        plan: Some(&plan),
        seed,
        cfg: SimConfig {
            max_batch: 4,
            max_wait_s: 0.02,
            slo_s: 5.0,
            ..SimConfig::default()
        },
        arrival_label: "poisson:40".to_string(),
        // PolicyKind::all() includes replan, which needs a control config
        // (static ζ here: no carbon signal attached), and resilient, which
        // needs its own plan (the static one doubles as a degenerate N+0).
        control: Some(Default::default()),
        replicas: None,
        failures: None,
        hazard: None,
        hazard_seed: 0,
        resilient_plan: Some(&plan),
        resilience: None,
    };
    let rows = compare(&spec, &queries, &arrivals, &PolicyKind::all()).unwrap();
    (rows, queries, sets)
}

#[test]
fn same_seed_and_config_give_byte_identical_metrics_json() {
    forall(Config::default().cases(6), |rng| {
        let seed = rng.next_u64();
        let (a, _, _) = run_compare(seed);
        let (b, _, _) = run_compare(seed);
        let ja = comparison_to_json(&a).to_string_pretty();
        let jb = comparison_to_json(&b).to_string_pretty();
        assert_eq!(ja, jb, "seed {seed} not byte-identical");
        // And per-policy artifacts individually.
        for (ma, mb) in a.iter().zip(&b) {
            assert_eq!(
                ma.to_json().to_string_pretty(),
                mb.to_json().to_string_pretty()
            );
        }
    });
}

/// The `--seeds N` harness fans policies × seeds over threads; two
/// invocations must still merge into byte-identical artifacts, with each
/// replicate under its own seed.
#[test]
fn parallel_seeds_compare_is_byte_identical() {
    forall(Config::default().cases(4), |rng| {
        let seed = rng.next_u64();
        let one = || {
            let mut rng = Rng::new(seed);
            let sets = random_sets(&mut rng, 3);
            let queries = shaped_workload(&mut rng.fork(1), 5, 80);
            let plan = plan_for(&sets, &queries, 1.0, seed);
            let spec = CompareSpec {
                sets: &sets,
                norm: plan.normalizer(),
                zeta: 1.0,
                plan: Some(&plan),
                seed,
                cfg: SimConfig {
                    max_batch: 4,
                    max_wait_s: 0.02,
                    slo_s: 5.0,
                    ..SimConfig::default()
                },
                arrival_label: "poisson:30".to_string(),
                control: Some(Default::default()),
                replicas: None,
                failures: None,
                hazard: None,
                hazard_seed: 0,
                resilient_plan: Some(&plan),
                resilience: None,
            };
            let grid = compare_replicated(
                &spec,
                &queries,
                Arrivals::Sampled(ArrivalProcess::Poisson { rate: 30.0 }),
                &PolicyKind::all(),
                3,
            )
            .unwrap();
            for runs in &grid {
                for (i, m) in runs.iter().enumerate() {
                    assert_eq!(m.seed, seed.wrapping_add(i as u64));
                }
            }
            replicated_to_json(&grid).to_string_pretty()
        };
        assert_eq!(one(), one(), "seed {seed} not byte-identical");
    });
}

#[test]
fn different_seeds_change_the_trace() {
    let (a, _, _) = run_compare(101);
    let (b, _, _) = run_compare(102);
    assert_ne!(
        comparison_to_json(&a).to_string_pretty(),
        comparison_to_json(&b).to_string_pretty()
    );
}

/// At ζ = 1 the plan is the minimum-energy assignment subject to Eq. 3;
/// any query-independent baseline whose realized assignment is itself
/// Eq. 3-feasible can therefore never beat it on total energy.
#[test]
fn plan_energy_never_beaten_by_feasible_query_independent_baselines() {
    forall(Config::default().cases(10), |rng| {
        let seed = rng.next_u64();
        let (rows, queries, sets) = run_compare(seed);
        let by_label = |label: &str| rows.iter().find(|m| m.policy == label).unwrap();
        let plan_m = by_label("plan");
        // The sim replays the exact workload the plan was solved on, so
        // every query follows the plan (no fallback decisions).
        assert_eq!(plan_m.plan_decisions.unwrap().1, 0, "seed {seed}");
        assert_eq!(plan_m.n_queries as usize, queries.len());

        let caps = capacity_bounds(
            CapacityMode::Eq3Only,
            &vec![1.0 / sets.len() as f64; sets.len()],
            queries.len(),
        );
        for label in ["round-robin", "random"] {
            let base = by_label(label);
            // Reconstruct the baseline's per-model counts from its nodes.
            let counts: Vec<u64> = base.nodes.iter().map(|nd| nd.queries).collect();
            let feasible = counts.iter().all(|&c| c >= 1)
                && counts
                    .iter()
                    .zip(&caps)
                    .all(|(&c, &cap)| c as usize <= cap);
            if !feasible {
                continue; // infeasible realizations are outside Eq. 3's space
            }
            // Headroom for COST_SCALE quantization: the solver optimizes
            // 1e-9-rounded normalized costs, so the true-energy optimum
            // can trail by up to n·1e-9·max_e — far below 0.01% of any
            // feasible baseline's total.
            let eps = 1e-4 * base.total_energy_j.abs() + 1e-3;
            assert!(
                plan_m.total_energy_j <= base.total_energy_j + eps,
                "seed {seed}: plan {} J > {label} {} J",
                plan_m.total_energy_j,
                base.total_energy_j
            );
        }
    });
}

#[test]
fn trace_replay_preserves_arrival_timestamps() {
    let mut rng = Rng::new(77);
    let sets = random_sets(&mut rng, 2);
    let queries: Vec<Query> = (0..10)
        .map(|i| Query {
            id: i,
            t_in: 32,
            t_out: 64,
        })
        .collect();
    // Deliberately unsorted timestamps: the simulator must order them.
    let arrivals: Vec<f64> = (0..10)
        .map(|i| if i % 2 == 0 { i as f64 } else { 20.0 - i as f64 })
        .collect();
    let norm = Normalizer::from_workload(&sets, &queries);
    let mut policy = ecoserve::sim::SimPolicy::new(
        PolicyKind::Greedy,
        &sets,
        norm,
        0.5,
        None,
        1,
        None,
    )
    .unwrap();
    let cfg = SimConfig {
        per_query: true,
        ..SimConfig::default()
    };
    let m = Simulator::new(&sets, cfg)
        .labeled("trace", 1, 0.5)
        .run(&queries, &arrivals, &mut policy)
        .unwrap();
    assert_eq!(m.n_queries, 10);
    let mut by_id = m.outcomes.clone().unwrap();
    by_id.sort_by_key(|o| o.id);
    for (o, want) in by_id.iter().zip(&arrivals) {
        assert_eq!(o.t_arrive, *want, "query {}", o.id);
        assert!(o.t_complete >= o.t_arrive);
    }
    assert_eq!(m.arrival, "trace");
}

/// The streaming histogram quantiles in the artifact agree with the exact
/// sorted-vector quantiles (recomputed from retained outcomes) to within
/// one bin ratio, on real simulated runs.
#[test]
fn streaming_quantiles_track_exact_quantiles_on_simulated_runs() {
    let ratio = 2f64.powf(1.0 / LOG_HIST_BINS_PER_OCTAVE as f64);
    forall(Config::default().cases(8), |rng| {
        let seed = rng.next_u64();
        let mut rng = Rng::new(seed);
        let sets = random_sets(&mut rng, 3);
        let n = 200 + rng.index(300);
        let queries = shaped_workload(&mut rng.fork(1), 8, n);
        let arrivals = ArrivalProcess::GammaBurst { rate: 60.0, cv2: 4.0 }
            .times(n, &mut rng.fork(2))
            .unwrap();
        let norm = Normalizer::from_workload(&sets, &queries);
        let mut policy = ecoserve::sim::SimPolicy::new(
            PolicyKind::Greedy,
            &sets,
            norm,
            0.5,
            None,
            seed,
            None,
        )
        .unwrap();
        let cfg = SimConfig {
            max_batch: 4,
            max_wait_s: 0.02,
            per_query: true,
            ..SimConfig::default()
        };
        let m = Simulator::new(&sets, cfg)
            .run(&queries, &arrivals, &mut policy)
            .unwrap();
        let outcomes = m.outcomes.as_ref().unwrap();
        let lats: Vec<f64> = outcomes.iter().map(|o| o.latency_s()).collect();
        for (est, q) in [(m.p50_latency_s, 0.5), (m.p95_latency_s, 0.95)] {
            // Exact nearest-rank quantile of the streamed observations.
            let mut sorted = lats.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let exact = sorted[(((sorted.len() - 1) as f64) * q).ceil() as usize];
            assert!(
                exact <= est * (1.0 + 1e-9) && exact >= est / ratio * (1.0 - 1e-9),
                "seed {seed}: hist {q}-quantile {est} vs exact {exact}"
            );
        }
        // The artifact's `exact` block uses the type-7 interpolated
        // quantile (the v1 convention) over the same observations.
        let json = m.to_json();
        let got = json.get("exact").get("p95_latency_s").as_f64().unwrap();
        assert!((got - quantile(&lats, 0.95)).abs() < 1e-9);
        // Means, maxima and totals are exact regardless of retention.
        assert!((m.max_latency_s - sorted_max(&lats)).abs() < 1e-12);
    });
}

fn sorted_max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(0.0f64, f64::max)
}

/// Golden: the committed version-6 artifact round-trips byte-exactly
/// through `SimMetrics::from_json` → `to_json`, and the version-1
/// through version-5 layouts are rejected with migration messages.
#[test]
fn metrics_artifact_golden_roundtrip_and_version_gate() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/sim_metrics_v6.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = Json::parse(&text).unwrap();
    let m = SimMetrics::from_json(&parsed).unwrap();
    assert_eq!(m.policy, "plan");
    assert_eq!(m.engine, "continuous");
    assert_eq!(m.seed, 42);
    assert_eq!(m.n_queries, 7);
    assert_eq!(m.latency_hist.n(), 7);
    assert_eq!(m.ttft_hist.n(), 7);
    assert_eq!(m.plan_decisions, Some((5, 2)));
    assert_eq!(m.ttft_slo_s, Some(1.0));
    assert_eq!(m.ttft_attainment, Some(1.0));
    // The fixture sets no TPOT SLO: the pair stays absent.
    assert_eq!(m.tpot_slo_s, None);
    assert_eq!(m.tpot_attainment, None);
    // The phase split partitions the recorded total.
    assert_eq!(m.prefill_energy_j + m.decode_energy_j, m.total_energy_j);
    // The cluster fields: a two-replica fleet under a three-event outage
    // script, with per-replica downtime and requeue accounting.
    assert_eq!(m.scenario, "chaos:3");
    assert_eq!(m.n_requeued, 2);
    assert_eq!(m.nodes.len(), 2);
    assert_eq!((m.nodes[0].replica, m.nodes[1].replica), (0, 1));
    assert_eq!(m.nodes[0].downtime_s, 1.5);
    assert_eq!(m.nodes[0].requeued, 2);
    assert_eq!(m.nodes[1].requeued, 0);
    // The resilience fields: per-replica survival counters partition the
    // run totals, and availability folds the failed query in.
    assert_eq!(m.n_failed, 1);
    assert_eq!((m.n_retries, m.n_hedges, m.n_breaker_trips), (3, 1, 1));
    assert_eq!(m.nodes[0].retries + m.nodes[1].retries, m.n_retries);
    assert_eq!(m.nodes[0].hedges + m.nodes[1].hedges, m.n_hedges);
    assert_eq!(
        m.nodes[0].breaker_trips + m.nodes[1].breaker_trips,
        m.n_breaker_trips
    );
    assert_eq!(m.availability, 0.875);
    assert_eq!(m.goodput_qps, 1.75);
    // A lean (no control plane) artifact parses with the control blocks
    // absent, and reserializes without inventing them.
    assert_eq!(m.replan_stats, None);
    assert_eq!(m.carbon, None);
    assert_eq!(m.zeta_trajectory, None);
    // Byte-exact reserialization pins the schema.
    assert_eq!(m.to_json().to_string_pretty(), text);

    for (fixture, tag) in [
        ("tests/fixtures/sim_metrics_v1.json", "version 1"),
        ("tests/fixtures/sim_metrics_v2.json", "version 2"),
        ("tests/fixtures/sim_metrics_v3.json", "version 3"),
        ("tests/fixtures/sim_metrics_v4.json", "version 4"),
        ("tests/fixtures/sim_metrics_v5.json", "version 5"),
    ] {
        let old_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(fixture);
        let old = Json::parse(&std::fs::read_to_string(&old_path).unwrap()).unwrap();
        let err = SimMetrics::from_json(&old).unwrap_err().to_string();
        assert!(err.contains(tag), "{fixture}: {err}");
        assert!(err.contains("regenerate"), "{fixture}: {err}");
    }
}

/// Switching engines must neither drop, duplicate, nor invent queries;
/// and because greedy routes time-independently while both engines charge
/// the fitted whole-query Eq. 6 energy at retirement, per-node and total
/// energy must agree across the switch to 1e-9.
#[test]
fn engine_switch_conserves_queries_and_energy() {
    forall(Config::default().cases(6), |rng| {
        let seed = rng.next_u64();
        let mut rng = Rng::new(seed);
        let n_models = 2 + rng.index(2);
        let sets = random_sets(&mut rng, n_models);
        let n = 60 + rng.index(120);
        let queries = shaped_workload(&mut rng.fork(1), 6, n);
        let arrivals = ArrivalProcess::Poisson { rate: 50.0 }
            .times(n, &mut rng.fork(2))
            .unwrap();
        let norm = Normalizer::from_workload(&sets, &queries);
        let run = |engine: EngineKind| {
            let mut policy = ecoserve::sim::SimPolicy::new(
                PolicyKind::Greedy,
                &sets,
                norm,
                0.5,
                None,
                seed,
                None,
            )
            .unwrap();
            let cfg = SimConfig {
                max_batch: 4,
                max_wait_s: 0.02,
                per_query: true,
                engine,
                ..SimConfig::default()
            };
            Simulator::new(&sets, cfg)
                .run(&queries, &arrivals, &mut policy)
                .unwrap()
        };
        let lock = run(EngineKind::Lockstep);
        let cont = run(EngineKind::Continuous);
        for m in [&lock, &cont] {
            assert_eq!(m.n_queries as usize, n, "seed {seed} ({})", m.engine);
            let outcomes = m.outcomes.as_ref().unwrap();
            // Every workload id retired exactly once.
            let mut ids: Vec<u64> = outcomes.iter().map(|o| o.id).collect();
            ids.sort_unstable();
            assert!(
                ids.iter().enumerate().all(|(i, &id)| id == i as u64),
                "seed {seed} ({}): ids are not exactly 0..n",
                m.engine
            );
            // Causality per lifecycle.
            for o in outcomes {
                assert!(
                    o.t_arrive <= o.t_start
                        && o.t_start <= o.t_first_token
                        && o.t_first_token <= o.t_complete,
                    "seed {seed} ({}): query {} lifecycle out of order",
                    m.engine,
                    o.id
                );
            }
            // Per-query energies sum to the node totals, which sum to the
            // run total, which the phase split partitions.
            let per_query: f64 = outcomes.iter().map(|o| o.energy_j).sum();
            let per_node: f64 = m.nodes.iter().map(|nd| nd.energy_j).sum();
            let tol = 1e-9 * per_node.abs().max(1.0);
            assert!((per_query - per_node).abs() <= tol, "seed {seed}");
            assert!((per_node - m.total_energy_j).abs() <= tol, "seed {seed}");
            assert!(
                (m.prefill_energy_j + m.decode_energy_j - m.total_energy_j).abs() <= tol,
                "seed {seed} ({}): phase split does not partition the total",
                m.engine
            );
            for nd in &m.nodes {
                assert!(
                    nd.prefill_j >= -1e-12 && nd.prefill_j <= nd.energy_j + tol,
                    "seed {seed}: node {} prefill_j out of range",
                    nd.model_id
                );
            }
        }
        // Identical routing → identical per-node loads and energy.
        let tol = 1e-9 * lock.total_energy_j.abs().max(1.0);
        assert!(
            (lock.total_energy_j - cont.total_energy_j).abs() <= tol,
            "seed {seed}: lockstep {} J vs continuous {} J",
            lock.total_energy_j,
            cont.total_energy_j
        );
        for (a, b) in lock.nodes.iter().zip(&cont.nodes) {
            assert_eq!(a.queries, b.queries, "seed {seed}: {}", a.model_id);
            assert!((a.energy_j - b.energy_j).abs() <= tol, "seed {seed}");
        }
    });
}

/// With a single slot per node the continuous engine serializes sequences
/// exactly as lockstep does; the acceptance bar pins their total energy
/// to 1e-9 agreement.
#[test]
fn batch_of_one_matches_lockstep_energy_to_1e9() {
    let mut rng = Rng::new(515);
    let sets = random_sets(&mut rng, 3);
    let n = 150;
    let queries = shaped_workload(&mut rng.fork(1), 5, n);
    let arrivals = ArrivalProcess::Poisson { rate: 30.0 }
        .times(n, &mut rng.fork(2))
        .unwrap();
    let norm = Normalizer::from_workload(&sets, &queries);
    let run = |engine: EngineKind| {
        let mut policy = ecoserve::sim::SimPolicy::new(
            PolicyKind::Greedy,
            &sets,
            norm,
            0.5,
            None,
            515,
            None,
        )
        .unwrap();
        let cfg = SimConfig {
            max_batch: 1,
            max_wait_s: 0.01,
            engine,
            ..SimConfig::default()
        };
        Simulator::new(&sets, cfg)
            .run(&queries, &arrivals, &mut policy)
            .unwrap()
    };
    let lock = run(EngineKind::Lockstep);
    let cont = run(EngineKind::Continuous);
    assert_eq!(lock.n_queries, cont.n_queries);
    assert!(
        (lock.total_energy_j - cont.total_energy_j).abs()
            <= 1e-9 * lock.total_energy_j.abs().max(1.0),
        "batch-1 energy: lockstep {} J vs continuous {} J",
        lock.total_energy_j,
        cont.total_energy_j
    );
    assert!(
        (lock.prefill_energy_j - cont.prefill_energy_j).abs()
            <= 1e-9 * lock.total_energy_j.abs().max(1.0)
    );
}

/// The continuous engine honors the same determinism contract as
/// lockstep: the full policy grid over one seeded trace, run twice,
/// merges into byte-identical artifacts.
#[test]
fn continuous_engine_is_byte_deterministic() {
    forall(Config::default().cases(4), |rng| {
        let seed = rng.next_u64();
        let one = || {
            let mut rng = Rng::new(seed);
            let sets = random_sets(&mut rng, 3);
            let queries = shaped_workload(&mut rng.fork(1), 5, 100);
            let arrivals = ArrivalProcess::GammaBurst { rate: 60.0, cv2: 4.0 }
                .times(100, &mut rng.fork(2))
                .unwrap();
            let plan = plan_for(&sets, &queries, 1.0, seed);
            let spec = CompareSpec {
                sets: &sets,
                norm: plan.normalizer(),
                zeta: 1.0,
                plan: Some(&plan),
                seed,
                cfg: SimConfig {
                    max_batch: 4,
                    max_wait_s: 0.02,
                    slo_s: 5.0,
                    engine: EngineKind::Continuous,
                    ..SimConfig::default()
                },
                arrival_label: "gamma:60:4".to_string(),
                control: Some(Default::default()),
                replicas: None,
                failures: None,
                hazard: None,
                hazard_seed: 0,
                resilient_plan: Some(&plan),
                resilience: None,
            };
            let rows = compare(&spec, &queries, &arrivals, &PolicyKind::all()).unwrap();
            for m in &rows {
                assert_eq!(m.engine, "continuous", "seed {seed}: {}", m.policy);
            }
            comparison_to_json(&rows).to_string_pretty()
        };
        assert_eq!(one(), one(), "seed {seed}: continuous run not byte-identical");
    });
}

/// Model sets where accuracy and energy are strongly anti-correlated:
/// the cheapest model is the least accurate. Pushing ζ up must then
/// strictly shed energy, which is what the carbon governor exploits.
fn anticorrelated_sets() -> Vec<ModelSet> {
    let mut rng = Rng::new(9);
    let mut sets = random_sets(&mut rng, 3);
    for (i, s) in sets.iter_mut().enumerate() {
        let scale = 1.0 + 4.0 * i as f64; // energy: 1×, 5×, 9×
        s.energy.coefs = [0.5 * scale, 8.0 * scale, 0.003 * scale];
        s.accuracy = AccuracyModel::new(&s.model_id, 40.0 + 15.0 * i as f64);
    }
    sets
}

fn control_cfg() -> ecoserve::control::ControlConfig {
    ecoserve::control::ControlConfig {
        replan_every: 16,
        slo_trigger_s: Some(0.5),
        carbon: Some(ecoserve::control::CarbonConfig {
            // One grid "day" per 6 simulated seconds (0.25 s windows): a
            // multi-second run sweeps the whole diurnal curve, trough to
            // peak, so the governor genuinely moves ζ.
            day_s: 6.0,
            ..ecoserve::control::CarbonConfig::typical(0.3, 0.95)
        }),
    }
}

/// The full control stack — closed-loop replanning under carbon-aware ζ
/// governance — is as deterministic as the static policies: same seed and
/// config, byte-identical artifacts (including the carbon and replan
/// blocks and the ζ trajectory).
#[test]
fn replan_with_carbon_is_byte_identical_across_runs() {
    let one = || {
        let mut rng = Rng::new(4242);
        let sets = anticorrelated_sets();
        let queries = shaped_workload(&mut rng.fork(1), 6, 300);
        let plan = plan_for(&sets, &queries, 0.3, 4242);
        let spec = CompareSpec {
            sets: &sets,
            norm: plan.normalizer(),
            zeta: 0.3,
            plan: Some(&plan),
            seed: 4242,
            cfg: SimConfig {
                max_batch: 4,
                max_wait_s: 0.02,
                slo_s: 5.0,
                ..SimConfig::default()
            },
            arrival_label: "gamma:60:4".to_string(),
            control: Some(control_cfg()),
            replicas: None,
            failures: None,
            hazard: None,
            hazard_seed: 0,
            resilient_plan: None,
            resilience: None,
        };
        let kinds = [PolicyKind::Plan, PolicyKind::Replan, PolicyKind::Greedy];
        let grid = compare_replicated(
            &spec,
            &queries,
            Arrivals::Sampled(ArrivalProcess::GammaBurst { rate: 60.0, cv2: 4.0 }),
            &kinds,
            2,
        )
        .unwrap();
        for runs in &grid {
            for m in runs {
                // Carbon metering covers every policy in the grid...
                assert!(m.carbon.is_some(), "{}: no carbon block", m.policy);
                assert!(m.carbon.as_ref().unwrap().total_g > 0.0);
                // ...but only replan rows carry the control-loop stats.
                assert_eq!(m.replan_stats.is_some(), m.policy == "replan");
                assert_eq!(m.zeta_trajectory.is_some(), m.policy == "replan");
            }
        }
        replicated_to_json(&grid).to_string_pretty()
    };
    let a = one();
    assert_eq!(a, one(), "control stack not byte-identical");
    assert!(a.contains("total_carbon_g"));
    // Per-run artifacts round-trip with control blocks intact.
}

/// With the carbon band floored at the static ζ, the governor only ever
/// pushes ζ *up* (toward energy) as the grid dirties — so on sets where
/// accuracy trades against energy, the replanned run never spends more
/// energy than the frozen static plan it replaces. This is the CI
/// sim-smoke gate, asserted in-process on a Gamma burst.
#[test]
fn carbon_governed_replan_never_spends_more_energy_than_the_static_plan() {
    let mut rng = Rng::new(7);
    let sets = anticorrelated_sets();
    let queries = shaped_workload(&mut rng.fork(1), 6, 400);
    let zeta = 0.3;
    let plan = plan_for(&sets, &queries, zeta, 7);
    let spec = CompareSpec {
        sets: &sets,
        norm: plan.normalizer(),
        zeta,
        plan: Some(&plan),
        seed: 7,
        cfg: SimConfig {
            max_batch: 4,
            max_wait_s: 0.02,
            slo_s: 5.0,
            ..SimConfig::default()
        },
        arrival_label: "gamma:60:4".to_string(),
        // Band floor = static ζ: replan's operational ζ ≥ the plan's.
        control: Some(control_cfg()),
        replicas: None,
        failures: None,
        hazard: None,
        hazard_seed: 0,
        resilient_plan: None,
        resilience: None,
    };
    let arrivals = ArrivalProcess::GammaBurst { rate: 60.0, cv2: 4.0 }
        .times(queries.len(), &mut Rng::new(7))
        .unwrap();
    let rows = compare(
        &spec,
        &queries,
        &arrivals,
        &[PolicyKind::Plan, PolicyKind::Replan],
    )
    .unwrap();
    let plan_m = &rows[0];
    let replan_m = &rows[1];
    let rs = replan_m.replan_stats.unwrap();
    assert!(rs.replans > 0, "control loop never re-solved");
    assert!(rs.planned_routed > 0, "deficit routing never engaged");
    // Small slack: the first `replan_every - 1` arrivals route via the
    // ζ-cost fallback before the first solve exists.
    let eps = 0.02 * plan_m.total_energy_j.abs();
    assert!(
        replan_m.total_energy_j <= plan_m.total_energy_j + eps,
        "replan {} J > plan {} J",
        replan_m.total_energy_j,
        plan_m.total_energy_j
    );
}
