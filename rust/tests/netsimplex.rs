//! Solver-equivalence properties for the network-simplex backend: the
//! primal simplex and the successive-shortest-paths solver optimize the
//! identical shape-level integer program, so their objectives must agree
//! to 1e-9 across capacity modes, ζ values, warm starts (ζ re-solves and
//! replica rescales), and degenerate instances (zero-multiplicity shapes,
//! saturated caps, single model, infeasible-then-relaxed capacity
//! vectors). CI's `bench-smoke` job keeps the performance side of the
//! same story honest.

use ecoserve::models::{AccuracyModel, ModelSet, Normalizer, Target, WorkloadModel};
use ecoserve::plan::{Planner, SolverKind};
use ecoserve::scheduler::{
    capacity_bounds, group_by_shape, solve_exact_bucketed, solve_exact_netsimplex,
    BucketedProblem, CapacityMode, CostMatrix, ShapeGroups,
};
use ecoserve::testkit::{forall, Config};
use ecoserve::util::Rng;
use ecoserve::workload::{Query, Shape};

/// Random paper-like model sets (same generator as tests/plan.rs).
fn random_sets(rng: &mut Rng, n_models: usize) -> Vec<ModelSet> {
    (0..n_models)
        .map(|i| {
            let scale = rng.range(0.5, 8.0);
            ModelSet {
                model_id: format!("m{i}"),
                energy: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::EnergyJ,
                    coefs: [0.5 * scale, 8.0 * scale, 0.003 * scale],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                runtime: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::RuntimeS,
                    coefs: [1e-3, 1e-2, 1e-6],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                accuracy: AccuracyModel::new(&format!("m{i}"), rng.range(40.0, 70.0)),
            }
        })
        .collect()
}

fn random_table(rng: &mut Rng, n_shapes: usize) -> Vec<(u32, u32)> {
    (0..n_shapes)
        .map(|_| {
            (
                rng.int_range(1, 2048) as u32,
                rng.int_range(1, 4096) as u32,
            )
        })
        .collect()
}

fn shaped_workload(rng: &mut Rng, table: &[(u32, u32)], n: usize, id0: usize) -> Vec<Query> {
    (0..n)
        .map(|i| {
            let (t_in, t_out) = table[rng.index(table.len())];
            Query {
                id: (id0 + i) as u32,
                t_in,
                t_out,
            }
        })
        .collect()
}

fn random_gammas(rng: &mut Rng, k: usize) -> Vec<f64> {
    let raw: Vec<f64> = (0..k).map(|_| rng.range(0.01, 1.0)).collect();
    let sum: f64 = raw.iter().sum();
    raw.iter().map(|g| g / sum).collect()
}

/// Cold reference: from-scratch bucketed SSP solve.
fn cold_objective(
    sets: &[ModelSet],
    queries: &[Query],
    gammas: &[f64],
    mode: CapacityMode,
    zeta: f64,
) -> f64 {
    let norm = Normalizer::from_shapes(sets, &group_by_shape(queries).shapes);
    let bp = BucketedProblem::build(sets, &norm, queries, zeta);
    let caps = capacity_bounds(mode, gammas, queries.len());
    solve_exact_bucketed(&bp, &caps).unwrap().objective
}

/// Hand-built bucketed instance with explicit multiplicities (zero
/// allowed): `shape_costs[k][i]`.
fn instance(shape_costs: Vec<Vec<f64>>, mult: Vec<usize>) -> BucketedProblem {
    let ns = shape_costs[0].len();
    assert_eq!(mult.len(), ns);
    let shapes: Vec<Shape> = (0..ns)
        .map(|i| Shape {
            t_in: i as u32 + 1,
            t_out: 1,
        })
        .collect();
    let mut shape_of = Vec::new();
    for (i, &m) in mult.iter().enumerate() {
        for _ in 0..m {
            shape_of.push(i);
        }
    }
    BucketedProblem {
        groups: ShapeGroups {
            shapes,
            multiplicity: mult,
            shape_of,
        },
        costs: CostMatrix::from_rows(shape_costs),
    }
}

#[test]
fn prop_netsimplex_matches_ssp_across_modes_and_zetas() {
    forall(Config::default().cases(25), |rng| {
        let n_models = 1 + rng.index(4);
        let sets = random_sets(rng, n_models);
        let n_shapes = 1 + rng.index(6);
        let table = random_table(rng, n_shapes);
        let nq = n_models + rng.index(40);
        let queries = shaped_workload(rng, &table, nq, 0);
        let gammas = random_gammas(rng, n_models);
        let zeta = rng.range(0.0, 1.0);
        let mode = if rng.chance(0.5) {
            CapacityMode::Eq3Only
        } else {
            CapacityMode::GammaHard // saturated caps: Σ caps == |Q|
        };

        let planner = Planner::new(&sets).gammas(&gammas).capacity(mode).zeta(zeta);
        let solve = |kind: SolverKind| {
            let mut s = planner.clone().solver(kind).session(&queries).unwrap();
            s.solve().unwrap();
            s.assignment().unwrap().clone()
        };
        let simplex = solve(SolverKind::NetworkSimplex);
        let ssp = solve(SolverKind::Bucketed);
        assert!(
            (simplex.objective - ssp.objective).abs() < 1e-9,
            "{mode:?} zeta={zeta}: simplex {} vs ssp {}",
            simplex.objective,
            ssp.objective
        );
        simplex.check_constraints(n_models).unwrap();
        let caps = capacity_bounds(mode, &gammas, nq);
        for (c, cap) in simplex.counts(n_models).iter().zip(&caps) {
            assert!(c <= cap);
        }
    });
}

#[test]
fn prop_netsimplex_rezeta_warm_matches_cold_sweep() {
    forall(Config::default().cases(15), |rng| {
        let n_models = 2 + rng.index(3);
        let sets = random_sets(rng, n_models);
        let table = random_table(rng, 2 + rng.index(5));
        let nq = n_models + rng.index(40);
        let queries = shaped_workload(rng, &table, nq, 0);
        let gammas = random_gammas(rng, n_models);
        let mode = if rng.chance(0.5) {
            CapacityMode::Eq3Only
        } else {
            CapacityMode::GammaHard
        };

        // One simplex session across the whole sweep: each rezeta step
        // reprices the previous basis instead of solving cold.
        let mut session = Planner::new(&sets)
            .gammas(&gammas)
            .capacity(mode)
            .zeta(0.0)
            .solver(SolverKind::NetworkSimplex)
            .session(&queries)
            .unwrap();
        for i in 0..5 {
            let zeta = i as f64 / 4.0;
            session.rezeta(zeta).unwrap();
            let got = session.assignment().unwrap().objective;
            let want = cold_objective(&sets, &queries, &gammas, mode, zeta);
            assert!(
                (got - want).abs() < 1e-9,
                "zeta={zeta}: simplex rezeta {got} vs cold ssp {want}"
            );
        }
    });
}

#[test]
fn prop_netsimplex_extend_warm_matches_cold() {
    forall(Config::default().cases(15), |rng| {
        let n_models = 2 + rng.index(3);
        let sets = random_sets(rng, n_models);
        let table = random_table(rng, 3 + rng.index(5));
        let nq0 = n_models + rng.index(30);
        let initial = shaped_workload(rng, &table, nq0, 0);
        let gammas = random_gammas(rng, n_models);
        let zeta = rng.range(0.0, 1.0);
        let mode = if rng.chance(0.5) {
            CapacityMode::Eq3Only
        } else {
            CapacityMode::GammaHard
        };

        let mut session = Planner::new(&sets)
            .gammas(&gammas)
            .capacity(mode)
            .zeta(zeta)
            .solver(SolverKind::NetworkSimplex)
            .session(&initial)
            .unwrap();
        session.solve().unwrap();

        let mut cumulative = initial;
        for batch_no in 0..3 {
            // Mostly known shapes (the basis-repair warm path), sometimes
            // new ones (the cold rebuild path) — both must agree with the
            // from-scratch SSP solve.
            let batch = if rng.chance(0.8) {
                let n = 1 + rng.index(20);
                shaped_workload(rng, &table, n, cumulative.len())
            } else {
                let wider = random_table(rng, 2);
                let n = 1 + rng.index(10);
                shaped_workload(rng, &wider, n, cumulative.len())
            };
            session.extend(&batch).unwrap();
            cumulative.extend_from_slice(&batch);

            let got = session.assignment().unwrap().objective;
            let want = cold_objective(&sets, &cumulative, &gammas, mode, zeta);
            assert!(
                (got - want).abs() < 1e-9,
                "batch {batch_no} ({mode:?}, |Q|={}): simplex {got} vs cold ssp {want}",
                cumulative.len()
            );
            session
                .assignment()
                .unwrap()
                .check_constraints(n_models)
                .unwrap();
        }
    });
}

#[test]
fn prop_zero_multiplicity_shapes_agree() {
    forall(Config::default().cases(30), |rng| {
        let ns = 2 + rng.index(5);
        let nm = 1 + rng.index(3);
        // At least one shape pinned to multiplicity zero.
        let mut mult: Vec<usize> = (0..ns).map(|_| rng.index(6)).collect();
        mult[rng.index(ns)] = 0;
        let nq: usize = mult.iter().sum();
        if nq < nm {
            return;
        }
        let costs: Vec<Vec<f64>> = (0..nm)
            .map(|_| (0..ns).map(|_| rng.range(-1.0, 1.0)).collect())
            .collect();
        let bp = instance(costs, mult);
        let caps: Vec<usize> = (0..nm).map(|_| 1 + rng.index(nq + 2)).collect();
        if caps.iter().sum::<usize>() < nq {
            return;
        }
        let a = solve_exact_netsimplex(&bp, &caps).unwrap();
        let b = solve_exact_bucketed(&bp, &caps).unwrap();
        assert!(
            (a.objective - b.objective).abs() < 1e-9,
            "simplex {} vs ssp {}",
            a.objective,
            b.objective
        );
        assert_eq!(a.model_of.len(), nq);
    });
}

#[test]
fn prop_infeasible_then_relaxed_caps_agree() {
    forall(Config::default().cases(30), |rng| {
        let ns = 1 + rng.index(4);
        let nm = 2 + rng.index(3);
        let mult: Vec<usize> = (0..ns).map(|_| 1 + rng.index(6)).collect();
        let nq: usize = mult.iter().sum();
        if nq < nm {
            return;
        }
        let costs: Vec<Vec<f64>> = (0..nm)
            .map(|_| (0..ns).map(|_| rng.range(-1.0, 1.0)).collect())
            .collect();
        let bp = instance(costs, mult);

        // Infeasible: one seat per model sums below the workload whenever
        // |Q| > K. Both backends must reject the instance.
        let caps: Vec<usize> = vec![1; nm];
        if caps.iter().sum::<usize>() < nq {
            assert!(solve_exact_netsimplex(&bp, &caps).is_err());
            assert!(solve_exact_bucketed(&bp, &caps).is_err());
        }

        // Relaxed: grow capacities until feasible; both succeed and agree.
        let mut relaxed = caps.clone();
        let mut k = 0usize;
        while relaxed.iter().sum::<usize>() < nq {
            relaxed[k % nm] += 1 + rng.index(3);
            k += 1;
        }
        let a = solve_exact_netsimplex(&bp, &relaxed).unwrap();
        let b = solve_exact_bucketed(&bp, &relaxed).unwrap();
        assert!(
            (a.objective - b.objective).abs() < 1e-9,
            "simplex {} vs ssp {}",
            a.objective,
            b.objective
        );
    });
}

#[test]
fn sweep_solver_accepts_the_netsimplex_backend() {
    // The Fig. 3 sweep entry point drives the backend by name end to end
    // (CLI `sweep-zeta --solver net-simplex` takes this exact path).
    let mut rng = Rng::new(0x51F3);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 6);
    let queries = shaped_workload(&mut rng, &table, 60, 0);
    let gammas = [0.2, 0.3, 0.5];
    let sweep = ecoserve::scheduler::sweep_solver(
        &sets,
        &queries,
        &gammas,
        3,
        CapacityMode::Eq3Only,
        SolverKind::parse("net-simplex").unwrap(),
        &mut rng,
    )
    .unwrap();
    assert_eq!(sweep.points.len(), 3);
    assert!(sweep
        .points
        .iter()
        .all(|p| p.eval.mean_energy_j.is_finite()));
}

// ---------------------------------------------------------------- rescale

/// Warm-started replica rescales on the net-simplex backend must agree
/// with the *other* exact backend solved cold on the same replicated
/// topology — a cross-solver check, so a warm-start bug cannot hide
/// behind a matching bug in its own cold path. Grow steps exercise the
/// pinned-basis warm start (fresh columns enter empty); shrink steps
/// under tight caps drop flow-carrying columns and take the documented
/// cold fallback.
#[test]
fn prop_netsimplex_rescale_matches_bucketed_cold_solves() {
    forall(Config::default().cases(14), |rng| {
        let n_models = 2 + rng.index(3);
        let sets = random_sets(rng, n_models);
        let table = random_table(rng, 3 + rng.index(4));
        let nq = 6 * n_models + rng.index(50);
        let queries = shaped_workload(rng, &table, nq, 0);
        let gammas = random_gammas(rng, n_models);
        let zeta = rng.range(0.0, 1.0);
        let mode = if rng.chance(0.5) {
            CapacityMode::Eq3Only
        } else {
            CapacityMode::GammaHard
        };

        let mut simplex = Planner::new(&sets)
            .gammas(&gammas)
            .capacity(mode)
            .zeta(zeta)
            .solver(SolverKind::NetworkSimplex)
            .session(&queries)
            .unwrap();
        simplex.solve().unwrap();

        let mut counts = vec![1usize; n_models];
        for _ in 0..5 {
            let k = rng.index(n_models);
            let c = 1 + rng.index(3);
            let mut target = counts.clone();
            target[k] = c;

            let mut bucketed = Planner::new(&sets)
                .gammas(&gammas)
                .capacity(mode)
                .zeta(zeta)
                .solver(SolverKind::Bucketed)
                .session(&queries)
                .unwrap();
            match (simplex.rescale(k, c), bucketed.set_replicas(&target)) {
                (Ok(()), Ok(())) => {
                    counts = target;
                    let got = simplex.assignment().unwrap().objective;
                    let want = bucketed.solve().unwrap().objective;
                    assert!(
                        (got - want).abs() < 1e-9,
                        "counts {counts:?} ({mode:?}, zeta={zeta}): \
                         net-simplex warm {got} vs bucketed cold {want}"
                    );
                }
                (Err(w), Err(c)) => {
                    // Same instructive error on both paths; the session
                    // keeps its old topology and stays solvable.
                    assert_eq!(w.to_string(), c.to_string());
                    assert_eq!(simplex.replicas().counts(), counts.as_slice());
                    simplex.solve().unwrap();
                }
                (w, c) => panic!(
                    "feasibility disagrees (warm ok={}, cold ok={})",
                    w.is_ok(),
                    c.is_ok()
                ),
            }
        }
    });
}
