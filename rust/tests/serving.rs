//! Integration test over the real serving stack (gated on `make
//! artifacts`): ζ-cost routing with γ quotas through actual PJRT engines,
//! and cross-layer consistency between the Rust serving path and the
//! fitted-model predictions.

use ecoserve::characterize::quick_fit;
use ecoserve::config::{llama_family, Partition};
use ecoserve::coordinator::{serve, Policy, Request, Router, ServeConfig};
use ecoserve::models::Normalizer;
use ecoserve::util::Rng;
use ecoserve::workload::Query;
use std::path::PathBuf;
use std::time::Instant;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn make_requests(n: u64, seed: u64) -> Vec<(Request, Query)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|id| {
            let t_in = rng.int_range(2, 40) as usize;
            let n_gen = rng.int_range(1, 8) as usize;
            let prompt: Vec<i32> = (0..t_in).map(|_| rng.int_range(1, 500) as i32).collect();
            (
                Request {
                    id,
                    prompt,
                    n_gen,
                    submitted: Instant::now(),
                },
                Query {
                    id: id as u32,
                    t_in: t_in as u32,
                    t_out: n_gen as u32,
                },
            )
        })
        .collect()
}

#[test]
fn zeta_router_with_quota_serves_and_respects_shares() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let family = llama_family();
    let fitted = quick_fit(&family, 42).unwrap();
    let requests = make_requests(30, 7);
    let probe: Vec<Query> = requests.iter().map(|(_, q)| *q).collect();
    let norm = Normalizer::from_workload(&fitted.sets, &probe);
    let partition = Partition::paper_case_study();

    // ζ=0: everything wants the 70B; the quota must push overflow down.
    let router = Router::new(fitted.sets.clone(), norm, 0.0, Policy::ZetaCost)
        .with_quota(&partition.gammas, 0.05);
    let ids: Vec<&str> = family.iter().map(|m| m.id).collect();
    let cfg = ServeConfig::new(artifacts_dir(), &ids);
    let (responses, metrics) = serve(&cfg, router, requests).unwrap();

    assert_eq!(responses.len(), 30);
    let m70 = metrics.per_model.get("llama2-70b").map(|m| m.requests).unwrap_or(0);
    // γ₃ = 0.75 (+slack+grace): the 70B must NOT take everything.
    assert!(m70 < 30, "quota should divert some load, got {m70}/30 on 70B");
    assert!(m70 >= 18, "the accurate model should still take the lion's share");
    // All three models hosted → at least two used under this workload.
    assert!(metrics.per_model.len() >= 2);
    // Every response has tokens within vocab.
    for r in &responses {
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.iter().all(|&t| (0..512).contains(&t)));
        assert!(r.latency_s > 0.0 && r.queue_s >= 0.0);
    }
}

#[test]
fn single_model_policy_equals_direct_engine_output() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // Serving through the coordinator must produce exactly the tokens the
    // engine produces directly — no corruption in routing/batching.
    let family = llama_family();
    let fitted = quick_fit(&family, 42).unwrap();
    let requests = make_requests(4, 11);
    let probe: Vec<Query> = requests.iter().map(|(_, q)| *q).collect();
    let norm = Normalizer::from_workload(&fitted.sets, &probe);
    let router = Router::new(fitted.sets.clone(), norm, 0.5, Policy::Single(0));

    let prompts: Vec<Vec<i32>> = requests.iter().map(|(r, _)| r.prompt.clone()).collect();
    let n_gen: Vec<usize> = requests.iter().map(|(r, _)| r.n_gen).collect();

    let cfg = ServeConfig::new(artifacts_dir(), &["llama2-7b"]);
    let (responses, _) = serve(&cfg, router, requests).unwrap();

    // Direct engine run with the same batch.
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest = ecoserve::runtime::Manifest::load(&artifacts_dir()).unwrap();
    let engine =
        ecoserve::runtime::Engine::load(&client, manifest.model("llama2-7b").unwrap()).unwrap();
    let direct = engine.generate(&prompts, &n_gen).unwrap();

    for (resp, want) in responses.iter().zip(direct.tokens) {
        assert_eq!(resp.tokens, want, "request {}", resp.id);
    }
}
