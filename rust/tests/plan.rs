//! Property and integration tests for the `ecoserve::plan` facade:
//! artifact round-trips, ζ re-solve, warm-started extension, and replica
//! `rescale` equivalence (to 1e-9 against cold solves, across both exact
//! backends, grow and shrink, including saturated caps and infeasible
//! shrinks erroring identically warm and cold), and backend ordering
//! (greedy never beats the exact optimum).

use ecoserve::models::{AccuracyModel, ModelSet, Normalizer, Target, WorkloadModel};
use ecoserve::plan::{Plan, Planner, SolverKind};
use ecoserve::scheduler::{
    capacity_bounds, group_by_shape, solve_exact_bucketed, BucketedFlow, BucketedProblem,
    CapacityMode,
};
use ecoserve::testkit::{forall, Config};
use ecoserve::util::Rng;
use ecoserve::workload::Query;

/// Random paper-like model sets (same generator as tests/properties.rs).
fn random_sets(rng: &mut Rng, n_models: usize) -> Vec<ModelSet> {
    (0..n_models)
        .map(|i| {
            let scale = rng.range(0.5, 8.0);
            ModelSet {
                model_id: format!("m{i}"),
                energy: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::EnergyJ,
                    coefs: [0.5 * scale, 8.0 * scale, 0.003 * scale],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                runtime: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::RuntimeS,
                    coefs: [1e-3, 1e-2, 1e-6],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                accuracy: AccuracyModel::new(&format!("m{i}"), rng.range(40.0, 70.0)),
            }
        })
        .collect()
}

/// Workload drawn from a small shape table (heavy duplication — the
/// bucketed regime).
fn shaped_workload(
    rng: &mut Rng,
    table: &[(u32, u32)],
    n: usize,
    id0: usize,
) -> Vec<Query> {
    (0..n)
        .map(|i| {
            let (t_in, t_out) = table[rng.index(table.len())];
            Query {
                id: (id0 + i) as u32,
                t_in,
                t_out,
            }
        })
        .collect()
}

fn random_table(rng: &mut Rng, n_shapes: usize) -> Vec<(u32, u32)> {
    (0..n_shapes)
        .map(|_| {
            (
                rng.int_range(1, 2048) as u32,
                rng.int_range(1, 4096) as u32,
            )
        })
        .collect()
}

fn random_gammas(rng: &mut Rng, k: usize) -> Vec<f64> {
    let raw: Vec<f64> = (0..k).map(|_| rng.range(0.01, 1.0)).collect();
    let sum: f64 = raw.iter().sum();
    raw.iter().map(|g| g / sum).collect()
}

/// Cold reference: from-scratch bucketed solve of a workload (the exact
/// hand-wired pipeline the facade replaced).
fn cold_objective(
    sets: &[ModelSet],
    queries: &[Query],
    gammas: &[f64],
    mode: CapacityMode,
    zeta: f64,
) -> f64 {
    let norm = Normalizer::from_shapes(sets, &group_by_shape(queries).shapes);
    let bp = BucketedProblem::build(sets, &norm, queries, zeta);
    let caps = capacity_bounds(mode, gammas, queries.len());
    solve_exact_bucketed(&bp, &caps).unwrap().objective
}

#[test]
fn plan_artifact_save_load_roundtrip_is_equal() {
    let mut rng = Rng::new(0xA57);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 6);
    let queries = shaped_workload(&mut rng, &table, 40, 0);

    let mut session = Planner::new(&sets)
        .gammas(&[0.2, 0.3, 0.5])
        .capacity(CapacityMode::Eq3Only)
        .zeta(0.4)
        .session(&queries)
        .unwrap();
    let plan = session.plan().unwrap();

    let path = std::path::Path::new(env!("CARGO_TARGET_TMPDIR")).join("roundtrip_plan.json");
    plan.save(&path).unwrap();
    let loaded = Plan::load(&path).unwrap();
    assert_eq!(plan, loaded, "save→load must be lossless");
    std::fs::remove_file(&path).ok();

    // The artifact expands back onto the same workload with matching
    // counts and objective.
    let a = loaded.assignment_for(&queries).unwrap();
    assert_eq!(
        a.counts(sets.len()),
        session.assignment().unwrap().counts(sets.len())
    );
    assert_eq!(a.objective, plan.objective);
}

#[test]
fn prop_rezeta_matches_cold_solves_along_sweep() {
    forall(Config::default().cases(20), |rng| {
        let n_models = 2 + rng.index(3);
        let sets = random_sets(rng, n_models);
        let n_shapes = 2 + rng.index(5);
        let table = random_table(rng, n_shapes);
        let nq = n_models + rng.index(40);
        let queries = shaped_workload(rng, &table, nq, 0);
        let gammas = random_gammas(rng, n_models);
        let mode = if rng.chance(0.5) {
            CapacityMode::Eq3Only
        } else {
            CapacityMode::GammaHard
        };

        let mut session = Planner::new(&sets)
            .gammas(&gammas)
            .capacity(mode)
            .zeta(0.0)
            .session(&queries)
            .unwrap();
        for i in 0..5 {
            let zeta = i as f64 / 4.0;
            session.rezeta(zeta).unwrap();
            let got = session.assignment().unwrap().objective;
            let want = cold_objective(&sets, &queries, &gammas, mode, zeta);
            assert!(
                (got - want).abs() < 1e-9,
                "zeta={zeta}: rezeta {got} vs cold {want}"
            );
        }
    });
}

#[test]
fn prop_warm_extend_matches_cold_bucketed_solve() {
    forall(Config::default().cases(25), |rng| {
        let n_models = 2 + rng.index(3);
        let sets = random_sets(rng, n_models);
        let n_shapes = 3 + rng.index(5);
        let table = random_table(rng, n_shapes);
        let nq0 = n_models + rng.index(30);
        let initial = shaped_workload(rng, &table, nq0, 0);
        let gammas = random_gammas(rng, n_models);
        let zeta = rng.range(0.0, 1.0);
        // GammaHard caps come from largest-remainder apportionment, which
        // is non-monotone in |Q| — shrinking caps must take the cold
        // fallback inside `BucketedFlow::extend`; Eq3Only caps grow
        // monotonically and exercise the warm path.
        let mode = if rng.chance(0.5) {
            CapacityMode::Eq3Only
        } else {
            CapacityMode::GammaHard
        };

        let mut session = Planner::new(&sets)
            .gammas(&gammas)
            .capacity(mode)
            .zeta(zeta)
            .session(&initial)
            .unwrap();
        session.solve().unwrap();

        let mut cumulative = initial;
        for batch_no in 0..3 {
            // Batches usually reuse known shapes (the warm path) but
            // occasionally bring new ones (forcing the cold rebuild path)
            // — both must agree with the from-scratch solve.
            let batch = if rng.chance(0.8) {
                let n = 1 + rng.index(20);
                shaped_workload(rng, &table, n, cumulative.len())
            } else {
                let wider = random_table(rng, 2);
                let n = 1 + rng.index(10);
                shaped_workload(rng, &wider, n, cumulative.len())
            };
            session.extend(&batch).unwrap();
            cumulative.extend_from_slice(&batch);

            let got = session.assignment().unwrap().objective;
            let want = cold_objective(&sets, &cumulative, &gammas, mode, zeta);
            assert!(
                (got - want).abs() < 1e-9,
                "batch {batch_no} ({mode:?}, |Q|={}): warm {got} vs cold {want}",
                cumulative.len()
            );
            assert_eq!(session.n_queries(), cumulative.len());
            session
                .assignment()
                .unwrap()
                .check_constraints(n_models)
                .unwrap();
        }
    });
}

/// Extend edge cases: an empty batch is exactly `solve()` (no state
/// disturbance), a batch of already-seen shapes stays on the warm path
/// without growing the shape set, and a stream of small extends lands on
/// the same optimum as one big extend of their concatenation.
#[test]
fn extend_edge_cases_empty_seen_only_and_split_batches() {
    let mut rng = Rng::new(0xE9E);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 5);
    let initial = shaped_workload(&mut rng, &table, 24, 0);
    let gammas = vec![1.0 / 3.0; 3];
    let zeta = 0.6;
    let planner = Planner::new(&sets).gammas(&gammas).zeta(zeta);

    // Empty batch before any solve: behaves as the first solve.
    let mut s = planner.session(&initial).unwrap();
    let obj0 = s.extend(&[]).unwrap().objective;
    assert_eq!(s.n_queries(), initial.len());
    // Empty batch after a solve: a no-op re-returning the optimum.
    let obj1 = s.extend(&[]).unwrap().objective;
    assert_eq!(obj0, obj1);
    let cold = cold_objective(&sets, &initial, &gammas, CapacityMode::Eq3Only, zeta);
    assert!((obj0 - cold).abs() < 1e-9, "session {obj0} vs cold {cold}");

    // A batch made only of already-seen shapes must not grow the shape
    // set (warm path) and must still match the from-scratch optimum.
    let n_shapes_before = s.n_shapes();
    let seen_batch = shaped_workload(&mut rng, &table, 17, initial.len());
    s.extend(&seen_batch).unwrap();
    assert_eq!(s.n_shapes(), n_shapes_before, "no new shape slots");
    let mut cumulative = initial.clone();
    cumulative.extend_from_slice(&seen_batch);
    let want = cold_objective(&sets, &cumulative, &gammas, CapacityMode::Eq3Only, zeta);
    let got = s.assignment().unwrap().objective;
    assert!((got - want).abs() < 1e-9, "warm {got} vs cold {want}");

    // Many small extends ≡ one large extend of the concatenation.
    let tail = shaped_workload(&mut rng, &table, 30, cumulative.len());
    let mut many = planner.session(&cumulative).unwrap();
    for chunk in tail.chunks(7) {
        many.extend(chunk).unwrap();
    }
    let mut one = planner.session(&cumulative).unwrap();
    one.extend(&tail).unwrap();
    let (a, b) = (
        many.assignment().unwrap().objective,
        one.assignment().unwrap().objective,
    );
    assert!((a - b).abs() < 1e-9, "split {a} vs single {b}");
    assert_eq!(many.n_queries(), one.n_queries());
}

#[test]
fn prop_greedy_never_beats_the_exact_optimum() {
    forall(Config::default().cases(30), |rng| {
        let n_models = 2 + rng.index(3);
        let sets = random_sets(rng, n_models);
        let n_shapes = 2 + rng.index(6);
        let table = random_table(rng, n_shapes);
        let nq = n_models + rng.index(40);
        let queries = shaped_workload(rng, &table, nq, 0);
        let gammas = random_gammas(rng, n_models);
        let zeta = rng.range(0.0, 1.0);

        let planner = Planner::new(&sets)
            .gammas(&gammas)
            .capacity(CapacityMode::GammaHard)
            .zeta(zeta);
        let solve = |kind: SolverKind| {
            let mut s = planner.clone().solver(kind).session(&queries).unwrap();
            s.solve().unwrap();
            s.assignment().unwrap().objective
        };
        let exact = solve(SolverKind::Bucketed);
        let greedy = solve(SolverKind::Greedy);
        assert!(
            greedy >= exact - 1e-9,
            "greedy {greedy} must not beat exact {exact}"
        );
    });
}

#[test]
fn extend_with_new_shapes_takes_the_cold_rebuild_path() {
    // `BucketedFlow::extend` warm-starts only when the shape set is
    // unchanged; a batch carrying brand-new shapes changes the shape count
    // and must force the documented cold-rebuild fallback — first checked
    // directly on the flow core, then through the session, where the
    // result must still equal a from-scratch solve of the cumulative
    // workload.
    let mut rng = Rng::new(0xC01D);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 5);
    let initial = shaped_workload(&mut rng, &table, 40, 0);
    let gammas = [0.25, 0.35, 0.4];

    // Direct: a solved BucketedFlow declines mismatched shape counts.
    let norm = Normalizer::from_shapes(&sets, &group_by_shape(&initial).shapes);
    let bp = BucketedProblem::build(&sets, &norm, &initial, 0.5);
    let caps = capacity_bounds(CapacityMode::Eq3Only, &gammas, initial.len());
    let mut flow = BucketedFlow::build(&bp, &caps).unwrap();
    flow.solve().unwrap();
    let grown_shape_count = vec![1usize; bp.groups.n_shapes() + 1];
    assert!(
        !flow.extend(&grown_shape_count, &caps).unwrap(),
        "a changed shape count must decline the warm path"
    );

    // Session: a batch of entirely new shapes (disjoint token range from
    // `random_table`'s 1..=2048 × 1..=4096) regroups and re-solves cold.
    let mut session = Planner::new(&sets)
        .gammas(&gammas)
        .capacity(CapacityMode::Eq3Only)
        .zeta(0.5)
        .session(&initial)
        .unwrap();
    session.solve().unwrap();
    let shapes_before = session.n_shapes();

    let fresh_table: Vec<(u32, u32)> = (0..4).map(|i| (5000 + i, 9000 + i)).collect();
    let batch = shaped_workload(&mut rng, &fresh_table, 15, initial.len());
    session.extend(&batch).unwrap();
    assert!(
        session.n_shapes() > shapes_before,
        "batch must have introduced new shapes"
    );

    let mut cumulative = initial;
    cumulative.extend_from_slice(&batch);
    let got = session.assignment().unwrap().objective;
    let want = cold_objective(&sets, &cumulative, &gammas, CapacityMode::Eq3Only, 0.5);
    assert!(
        (got - want).abs() < 1e-9,
        "cold-rebuild extend {got} vs from-scratch {want}"
    );
}

#[test]
fn rezeta_and_extend_interleave_consistently() {
    // A ζ change immediately followed by a batch (the carbon-aware loop's
    // shape) must equal the cold solve of the cumulative workload at the
    // new ζ.
    let mut rng = Rng::new(0xCAFE);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 8);
    let initial = shaped_workload(&mut rng, &table, 50, 0);
    let gammas = [0.2, 0.3, 0.5];

    let mut session = Planner::new(&sets)
        .gammas(&gammas)
        .capacity(CapacityMode::Eq3Only)
        .zeta(0.5)
        .session(&initial)
        .unwrap();
    session.solve().unwrap();

    let mut cumulative = initial;
    for (i, zeta) in [0.3, 0.3, 0.9].into_iter().enumerate() {
        let batch = shaped_workload(&mut rng, &table, 20, cumulative.len());
        session.set_zeta(zeta);
        session.extend(&batch).unwrap();
        cumulative.extend_from_slice(&batch);
        let got = session.assignment().unwrap().objective;
        let want = cold_objective(&sets, &cumulative, &gammas, CapacityMode::Eq3Only, zeta);
        assert!(
            (got - want).abs() < 1e-9,
            "step {i}: interleaved {got} vs cold {want}"
        );
    }
}

#[test]
fn solver_backends_share_the_interface() {
    // Every backend solves the same instance through the facade and
    // reports a real (finite) objective; exact backends agree, heuristics
    // and baselines are no better.
    let mut rng = Rng::new(0xBEE);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 5);
    let queries = shaped_workload(&mut rng, &table, 60, 0);
    let planner = Planner::new(&sets)
        .gammas(&[0.25, 0.35, 0.4])
        .capacity(CapacityMode::GammaHard)
        .zeta(0.6)
        .seed(7);

    let solve = |kind: SolverKind| {
        let mut s = planner.clone().solver(kind).session(&queries).unwrap();
        s.solve().unwrap();
        s.assignment().unwrap().clone()
    };
    let bucketed = solve(SolverKind::Bucketed);
    let dense = solve(SolverKind::Dense);
    assert!((bucketed.objective - dense.objective).abs() < 1e-9);
    // The network-simplex backend solves the same integer program exactly.
    let simplex = solve(SolverKind::NetworkSimplex);
    assert!((bucketed.objective - simplex.objective).abs() < 1e-9);
    // Greedy obeys the same capacities, so it cannot beat the optimum.
    let greedy = solve(SolverKind::Greedy);
    assert!(greedy.objective >= bucketed.objective - 1e-9);
    // The query-independent baselines ignore capacities but must still
    // report a real (finite) blend objective over the full workload.
    for kind in [
        SolverKind::RoundRobin,
        SolverKind::Random,
        SolverKind::Single(1),
    ] {
        let a = solve(kind);
        assert!(a.objective.is_finite(), "{kind:?} must report a real objective");
        assert_eq!(a.model_of.len(), queries.len());
    }
}

/// Golden-fixture forward-compat: the committed v1 artifact must keep
/// loading exactly (field-for-field and byte-for-byte on re-save), and a
/// future-versioned envelope must be rejected with a clear error — the
/// contract that lets old plans outlive layout changes.
#[test]
fn golden_v1_plan_fixture_round_trips_and_gates_versions() {
    use ecoserve::plan::{ShapeFlow, PLAN_FORMAT, PLAN_VERSION};
    use ecoserve::util::Json;
    use ecoserve::workload::Shape;

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/plan_v1.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let plan = Plan::load(&path).unwrap();

    let expected = Plan {
        version: 1,
        zeta: 0.375,
        gammas: vec![0.25, 0.75],
        mode: CapacityMode::Eq3Only,
        solver: "bucketed".to_string(),
        model_ids: vec!["small".to_string(), "big".to_string()],
        n_queries: 5,
        objective: -0.125,
        norm_max: [123.5, 66_000.0, 9.25],
        shape_flows: vec![
            ShapeFlow {
                shape: Shape { t_in: 8, t_out: 16 },
                flows: vec![2, 1],
            },
            ShapeFlow {
                shape: Shape { t_in: 100, t_out: 7 },
                flows: vec![0, 2],
            },
        ],
    };
    assert_eq!(plan, expected, "v1 fixture no longer parses field-for-field");

    // Re-serialization reproduces the committed bytes exactly: the writer
    // (key order, indentation, number formatting) is part of the format.
    assert_eq!(plan.to_json().to_string_pretty(), text);
    // And semantically: parse(fixture) == to_json(load(fixture)).
    assert_eq!(Json::parse(&text).unwrap(), plan.to_json());

    // An unknown (newer) version in the envelope is rejected, loudly.
    let mut doc = plan.to_json();
    if let Json::Obj(m) = &mut doc {
        m.insert("version".into(), Json::num((PLAN_VERSION + 1) as f64));
    }
    let tmp = std::path::Path::new(env!("CARGO_TARGET_TMPDIR")).join("plan_future.json");
    std::fs::write(&tmp, doc.to_string_pretty()).unwrap();
    let err = Plan::load(&tmp).unwrap_err().to_string();
    std::fs::remove_file(&tmp).ok();
    assert!(
        err.contains("newer than supported"),
        "unclear future-version error: {err}"
    );
    assert!(err.contains(&format!("{}", PLAN_VERSION + 1)), "{err}");

    // A foreign format marker is rejected too.
    let mut doc = plan.to_json();
    if let Json::Obj(m) = &mut doc {
        m.insert("format".into(), Json::str("not.a.plan"));
    }
    let err = Plan::from_json(&doc).unwrap_err().to_string();
    assert!(err.contains("not an ecoserve plan"), "{err}");
    assert_eq!(PLAN_FORMAT, "ecoserve.plan");
}

#[test]
fn prop_sketch_fed_plans_are_byte_identical_to_materialized() {
    // The streaming sketch path must not be a "close enough"
    // approximation: an exact sketch carries the same shapes in the same
    // first-appearance order with the same multiplicities, so the packaged
    // artifact — every serialized byte of it — must equal the one from a
    // materialized `Vec<Query>` session.
    use ecoserve::workload::ShapeSketch;

    forall(Config::default().cases(20), |rng| {
        let n_models = 2 + rng.index(3);
        let sets = random_sets(rng, n_models);
        let n_shapes = 2 + rng.index(7);
        let table = random_table(rng, n_shapes);
        let nq = n_models + rng.index(60);
        let queries = shaped_workload(rng, &table, nq, 0);
        let gammas = random_gammas(rng, n_models);
        let zeta = rng.range(0.0, 1.0);
        let mode = if rng.chance(0.5) {
            CapacityMode::Eq3Only
        } else {
            CapacityMode::GammaHard
        };
        let sketch = ShapeSketch::from_queries(&queries);
        assert!(sketch.is_exact());
        assert_eq!(sketch.n_queries(), queries.len() as u64);

        for kind in [SolverKind::Bucketed, SolverKind::NetworkSimplex] {
            let planner = Planner::new(&sets)
                .gammas(&gammas)
                .capacity(mode)
                .zeta(zeta)
                .solver(kind);
            let materialized = planner.plan(&queries).unwrap();
            let sketched = planner.plan_from_sketch(&sketch).unwrap();
            assert_eq!(
                sketched.to_json().to_string_pretty(),
                materialized.to_json().to_string_pretty(),
                "{kind:?} ({mode:?}, zeta={zeta}, |Q|={nq}): sketch-fed plan drifted"
            );
        }
    });
}

#[test]
fn sketch_rezeta_matches_fresh_sketch_sessions() {
    // Warm ζ re-solves on a sketch-fed net-simplex session must package
    // the same artifact bytes as a cold sketch session opened at that ζ.
    use ecoserve::workload::ShapeSketch;

    let mut rng = Rng::new(0x5EE7);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 6);
    let queries = shaped_workload(&mut rng, &table, 80, 0);
    let gammas = [0.3, 0.3, 0.4];
    let sketch = ShapeSketch::from_queries(&queries);

    let planner = Planner::new(&sets)
        .gammas(&gammas)
        .capacity(CapacityMode::Eq3Only)
        .solver(SolverKind::NetworkSimplex);
    let mut warm = planner.clone().zeta(0.0).from_sketch(&sketch).unwrap();
    warm.solve_shapes().unwrap();
    for i in 0..5 {
        let zeta = i as f64 / 4.0;
        warm.rezeta_shapes(zeta).unwrap();
        let fresh = planner.clone().zeta(zeta).plan_from_sketch(&sketch).unwrap();
        assert_eq!(
            warm.plan().unwrap().to_json().to_string_pretty(),
            fresh.to_json().to_string_pretty(),
            "zeta={zeta}: warm sketch rezeta drifted from cold"
        );
    }

    // And through the on-disk artifact path: the saved bytes of a
    // sketch-fed plan equal the saved bytes of the materialized plan.
    let dir = std::path::Path::new(env!("CARGO_TARGET_TMPDIR"));
    let sketch_path = dir.join("sketch_fed.json");
    let mat_path = dir.join("materialized.json");
    let p = planner.clone().zeta(0.5);
    p.plan_from_sketch(&sketch).unwrap().save(&sketch_path).unwrap();
    p.plan(&queries).unwrap().save(&mat_path).unwrap();
    let a = std::fs::read(&sketch_path).unwrap();
    let b = std::fs::read(&mat_path).unwrap();
    std::fs::remove_file(&sketch_path).ok();
    std::fs::remove_file(&mat_path).ok();
    assert_eq!(a, b, "saved artifacts must be byte-identical");
}

#[test]
fn sketch_sessions_gate_the_query_level_api_and_vice_versa() {
    // Sketch-fed sessions have no per-query identity, so the per-query
    // API must refuse loudly (not panic, not silently mis-answer). A
    // query-backed session, by contrast, supports *both* granularities:
    // shape-level solves are the online controller's re-solve surface.
    // Per-query-only backends cannot solve shape-level instances at all.
    use ecoserve::workload::ShapeSketch;

    let mut rng = Rng::new(0x51DE);
    let sets = random_sets(&mut rng, 2);
    let table = random_table(&mut rng, 4);
    let queries = shaped_workload(&mut rng, &table, 30, 0);
    let sketch = ShapeSketch::from_queries(&queries);
    let planner = Planner::new(&sets).gammas(&[0.5, 0.5]).zeta(0.5);

    let mut sketch_session = planner.from_sketch(&sketch).unwrap();
    assert!(sketch_session.is_sketch_fed());
    assert_eq!(sketch_session.n_queries(), queries.len());
    assert!(sketch_session.solve().is_err(), "per-query solve must bail");
    assert!(
        sketch_session.extend(&queries[..1]).is_err(),
        "per-query extend must bail"
    );
    sketch_session.solve_shapes().unwrap();
    let plan = sketch_session.plan().unwrap();
    assert_eq!(plan.n_queries, queries.len());

    let mut query_session = planner.session(&queries).unwrap();
    assert!(!query_session.is_sketch_fed());
    // Shape-level solve on a query-backed session: same optimum as the
    // per-query solve, flows conserving every shape's multiplicity.
    let shape_obj = query_session.solve_shapes().unwrap().objective;
    let flows = query_session.current_flows().unwrap();
    for (row, &m) in flows.iter().zip(&query_session.groups().multiplicity) {
        assert_eq!(row.iter().sum::<usize>(), m);
    }
    let query_obj = query_session.solve().unwrap().objective;
    assert!(
        (shape_obj - query_obj).abs() < 1e-9,
        "shape-level {shape_obj} vs per-query {query_obj}"
    );

    let mut greedy = planner
        .clone()
        .solver(SolverKind::Greedy)
        .from_sketch(&sketch)
        .unwrap();
    let err = greedy.solve_shapes().unwrap_err().to_string();
    assert!(
        err.contains("shape-level"),
        "greedy must explain it cannot solve sketch-fed instances: {err}"
    );
}

// ---------------------------------------------------------------- rescale

/// Cold reference for a replicated topology: a fresh session with the
/// target counts installed wholesale, solved from scratch.
fn cold_replicated_objective(
    sets: &[ModelSet],
    queries: &[Query],
    gammas: &[f64],
    mode: CapacityMode,
    zeta: f64,
    kind: SolverKind,
    counts: &[usize],
) -> anyhow::Result<f64> {
    let mut s = Planner::new(sets)
        .gammas(gammas)
        .capacity(mode)
        .zeta(zeta)
        .solver(kind)
        .session(queries)?;
    s.set_replicas(counts)?;
    Ok(s.solve()?.objective)
}

#[test]
fn prop_warm_rescale_matches_cold_replicated_solves() {
    // A random walk of single-model rescales (grow and shrink, both
    // capacity modes) must land on the cold optimum of each visited
    // topology, and an infeasible step must error with the exact message
    // the cold path gives — leaving the session on its old topology.
    forall(Config::default().cases(14), |rng| {
        let n_models = 2 + rng.index(3);
        let sets = random_sets(rng, n_models);
        let table = random_table(rng, 3 + rng.index(4));
        let nq = 6 * n_models + rng.index(60);
        let queries = shaped_workload(rng, &table, nq, 0);
        let gammas = random_gammas(rng, n_models);
        let zeta = rng.range(0.0, 1.0);
        let mode = if rng.chance(0.5) {
            CapacityMode::Eq3Only
        } else {
            CapacityMode::GammaHard
        };
        let steps: Vec<(usize, usize)> = (0..4)
            .map(|_| (rng.index(n_models), 1 + rng.index(3)))
            .collect();

        for kind in [SolverKind::Bucketed, SolverKind::NetworkSimplex] {
            let mut session = Planner::new(&sets)
                .gammas(&gammas)
                .capacity(mode)
                .zeta(zeta)
                .solver(kind)
                .session(&queries)
                .unwrap();
            session.solve().unwrap();
            let mut counts = vec![1usize; n_models];
            for &(k, c) in &steps {
                let mut target = counts.clone();
                target[k] = c;
                let warm = session.rescale(k, c);
                let want = cold_replicated_objective(
                    &sets, &queries, &gammas, mode, zeta, kind, &target,
                );
                match (warm, want) {
                    (Ok(()), Ok(want)) => {
                        counts = target;
                        let got = session.assignment().unwrap().objective;
                        assert!(
                            (got - want).abs() < 1e-9,
                            "{kind:?} ({mode:?}, counts {counts:?}): warm {got} vs cold {want}"
                        );
                        assert_eq!(session.replicas().counts(), counts.as_slice());
                    }
                    (Err(w), Err(c)) => {
                        assert_eq!(
                            w.to_string(),
                            c.to_string(),
                            "warm and cold must report the same instructive error"
                        );
                        // The failed step leaves the session untouched…
                        assert_eq!(session.replicas().counts(), counts.as_slice());
                        // …and still solvable at its old topology.
                        session.solve().unwrap();
                    }
                    (w, c) => panic!(
                        "{kind:?}: warm/cold feasibility disagrees \
                         (warm ok={}, cold ok={})",
                        w.is_ok(),
                        c.is_ok()
                    ),
                }
            }
        }
    });
}

#[test]
fn rescale_grow_and_shrink_under_saturated_caps() {
    // GammaHard caps sum exactly to |Q|, so every capacity is tight and a
    // shrink drops columns that carried flow — the documented cold-
    // fallback trigger for the warm-start backend. Both exact backends
    // must match the from-scratch optimum at every step.
    let mut rng = Rng::new(0x5CA1E);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 5);
    let queries = shaped_workload(&mut rng, &table, 60, 0);
    let gammas = [0.25, 0.35, 0.4];

    for kind in [SolverKind::Bucketed, SolverKind::NetworkSimplex] {
        let mut session = Planner::new(&sets)
            .gammas(&gammas)
            .capacity(CapacityMode::GammaHard)
            .zeta(0.5)
            .solver(kind)
            .session(&queries)
            .unwrap();
        session.solve().unwrap();
        let mut counts = vec![1usize; 3];
        for (k, c) in [(0, 3), (2, 2), (0, 1), (2, 1), (1, 3), (1, 1)] {
            counts[k] = c;
            session.rescale(k, c).unwrap();
            let got = session.assignment().unwrap().objective;
            let want = cold_replicated_objective(
                &sets,
                &queries,
                &gammas,
                CapacityMode::GammaHard,
                0.5,
                kind,
                &counts,
            )
            .unwrap();
            assert!(
                (got - want).abs() < 1e-9,
                "{kind:?} (counts {counts:?}): warm {got} vs cold {want}"
            );
        }
    }
}

#[test]
fn single_replica_topologies_package_byte_identical_artifacts() {
    // R=1 is the degenerate replica topology: installing it explicitly,
    // or a same-count rescale, must be a no-op — the packaged artifact is
    // byte-for-byte the plain session's. And for the cold-re-solving
    // bucketed backend, a grow→shrink cycle back to uniform restores the
    // baseline bytes exactly.
    let mut rng = Rng::new(0x1E91);
    let sets = random_sets(&mut rng, 3);
    let table = random_table(&mut rng, 5);
    let queries = shaped_workload(&mut rng, &table, 45, 0);
    let gammas = [0.3, 0.3, 0.4];

    for kind in [SolverKind::Bucketed, SolverKind::NetworkSimplex] {
        let planner = Planner::new(&sets)
            .gammas(&gammas)
            .capacity(CapacityMode::Eq3Only)
            .zeta(0.4)
            .solver(kind);
        let baseline = planner
            .clone()
            .session(&queries)
            .unwrap()
            .plan()
            .unwrap()
            .to_json()
            .to_string_pretty();

        let mut explicit = planner.clone().session(&queries).unwrap();
        explicit.set_replicas(&[1, 1, 1]).unwrap();
        assert!(explicit.replicas().is_uniform());
        assert_eq!(
            explicit.plan().unwrap().to_json().to_string_pretty(),
            baseline,
            "{kind:?}: explicit all-ones topology drifted from the plain session"
        );

        let mut noop = planner.clone().session(&queries).unwrap();
        noop.solve().unwrap();
        noop.rescale(1, 1).unwrap();
        assert_eq!(
            noop.plan().unwrap().to_json().to_string_pretty(),
            baseline,
            "{kind:?}: same-count rescale must not disturb the artifact"
        );
    }

    // Bucketed re-solves cold after every rescale, so returning to the
    // uniform topology reproduces the baseline solve deterministically.
    let planner = Planner::new(&sets)
        .gammas(&gammas)
        .capacity(CapacityMode::Eq3Only)
        .zeta(0.4)
        .solver(SolverKind::Bucketed);
    let baseline = planner
        .clone()
        .session(&queries)
        .unwrap()
        .plan()
        .unwrap()
        .to_json()
        .to_string_pretty();
    let mut cycled = planner.session(&queries).unwrap();
    cycled.solve().unwrap();
    cycled.rescale(0, 3).unwrap();
    cycled.rescale(0, 1).unwrap();
    assert!(cycled.replicas().is_uniform());
    assert_eq!(
        cycled.plan().unwrap().to_json().to_string_pretty(),
        baseline,
        "grow→shrink cycle back to R=1 must restore the uniform artifact"
    );
}

#[test]
fn shrink_to_infeasible_reports_the_instructive_error() {
    // A workload of |Q| queries cannot feed more than |Q| replica columns
    // one query each (Eq. 3): the rescale must refuse with the same
    // message as a cold set_replicas, and leave the session solvable.
    let mut rng = Rng::new(0xFEA5);
    let sets = random_sets(&mut rng, 2);
    let table = random_table(&mut rng, 3);
    let queries = shaped_workload(&mut rng, &table, 4, 0);

    for kind in [SolverKind::Bucketed, SolverKind::NetworkSimplex] {
        let mut session = Planner::new(&sets)
            .gammas(&[0.5, 0.5])
            .capacity(CapacityMode::Eq3Only)
            .zeta(0.5)
            .solver(kind)
            .session(&queries)
            .unwrap();
        session.solve().unwrap();
        // 4 queries, target topology [4, 1] → 5 columns: infeasible.
        let warm = session.rescale(0, 4).unwrap_err().to_string();
        assert!(warm.contains("Eq. 3"), "{kind:?}: {warm}");
        assert!(
            warm.contains("shrink the replica set or grow the workload"),
            "{kind:?}: {warm}"
        );
        let mut cold = Planner::new(&sets)
            .gammas(&[0.5, 0.5])
            .capacity(CapacityMode::Eq3Only)
            .zeta(0.5)
            .solver(kind)
            .session(&queries)
            .unwrap();
        let cold_err = cold.set_replicas(&[4, 1]).unwrap_err().to_string();
        assert_eq!(warm, cold_err, "{kind:?}: warm and cold errors diverged");
        // The refused rescale left the session untouched and solvable.
        assert!(session.replicas().is_uniform());
        session.solve().unwrap();
    }
}
