//! Integration test: the complete offline pipeline — characterize → ANOVA
//! → fit → normalize → optimize → evaluate — must reproduce the paper's
//! qualitative results end to end (DESIGN.md §5 statistical targets).

use ecoserve::characterize::{self, Campaign};
use ecoserve::config::{llama_family, swing_node, ExperimentConfig, Partition};
use ecoserve::hardware::Node;
use ecoserve::models::fit_all;
use ecoserve::perfmodel::Cluster;
use ecoserve::scheduler::{sweep_mode, CapacityMode};
use ecoserve::stats;
use ecoserve::util::Rng;
use ecoserve::workload::{generate, AlpacaParams};

fn family_rows(
    cfg: &ExperimentConfig,
    trials: usize,
    seed: u64,
) -> Vec<characterize::Row> {
    let campaign = Campaign::new(Cluster::new(Node::new(swing_node())), cfg.clone());
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();
    for spec in llama_family() {
        rows.extend(characterize::rows_from_cells(&campaign.grid(
            &spec,
            trials,
            &mut rng,
        )));
    }
    rows
}

#[test]
fn full_offline_pipeline_matches_paper_shape() {
    let mut cfg = ExperimentConfig::default();
    cfg.grid_levels = vec![8, 32, 128, 512, 2048];
    let rows = family_rows(&cfg, 3, 99);

    // --- Table 2 shape (model as blocking factor) --------------------------
    let e_obs = characterize::anova_blocks(&rows, |r| r.total_energy_j());
    let anova = stats::two_way_blocked(&e_obs, "in", "out").unwrap();
    assert!(anova.factor_b.f_stat > anova.factor_a.f_stat);
    assert!(anova.factor_a.p_value < 0.01);
    assert!(anova.factor_b.p_value < 1e-20);
    assert!(anova.interaction.p_value < 0.01);

    // --- Table 3 shape ---------------------------------------------------
    let family = llama_family();
    let sets = fit_all(&family, &rows).unwrap();
    for s in &sets {
        assert!(s.energy.r2 > 0.96, "{} energy R² {}", s.model_id, s.energy.r2);
        assert!(s.runtime.r2 > 0.96, "{} runtime R² {}", s.model_id, s.runtime.r2);
        assert!(s.energy.p_value < 1e-40);
    }
    // Energy cost ordering follows model size on the dominant (output)
    // term and on total predictions. (The interaction term α₂ does NOT
    // follow size: Llama-2 7B uses MHA, so its KV cache is larger per
    // token than the 70B's GQA cache — a real effect, not a bug.)
    assert!(sets[0].energy.coefs[1] < sets[1].energy.coefs[1]);
    assert!(sets[1].energy.coefs[1] < sets[2].energy.coefs[1]);
    // Ordering checks at the paper's own operating points (Fig. 1: vary
    // input with τ_out = 32; Fig. 2: vary output with τ_in = 32). At large
    // (τ_in AND τ_out) the 13B (MHA → big KV cache) genuinely crosses the
    // 70B (GQA) on runtime, so we do not assert there.
    for (ti, to) in [(32.0, 32.0), (512.0, 32.0), (32.0, 512.0), (2048.0, 32.0)] {
        let e: Vec<f64> = sets.iter().map(|s| s.energy.predict(ti, to)).collect();
        assert!(e[0] < e[1] && e[1] < e[2], "energy ({ti},{to}): {e:?}");
        let r: Vec<f64> = sets.iter().map(|s| s.runtime.predict(ti, to)).collect();
        assert!(r[0] < r[1], "runtime ({ti},{to}): {r:?}");
    }

    // --- Fig. 3 shape ----------------------------------------------------
    let mut rng = Rng::new(777);
    let queries = generate(400, &AlpacaParams::default(), &mut rng);
    let partition = Partition::paper_case_study();
    let sweep = sweep_mode(
        &sets,
        &queries,
        &partition.gammas,
        7,
        CapacityMode::Eq3Only,
        &mut rng,
    )
    .unwrap();

    // Energy monotone non-increasing in ζ; accuracy non-increasing.
    let pts = &sweep.points;
    for w in pts.windows(2) {
        assert!(w[1].eval.mean_energy_j <= w[0].eval.mean_energy_j + 1e-9);
        assert!(w[1].eval.mean_accuracy <= w[0].eval.mean_accuracy + 1e-9);
    }
    // The frontier spans a real range (the whole point of the paper).
    let e0 = pts.first().unwrap().eval.mean_energy_j;
    let e1 = pts.last().unwrap().eval.mean_energy_j;
    assert!(
        e0 / e1 > 2.0,
        "ζ should buy at least 2× mean-energy reduction: {e0} → {e1}"
    );
    // Scheduler at ζ=1 beats every query-independent baseline on energy.
    for (label, ev) in &sweep.baselines {
        if label.starts_with("single:llama2-7b") {
            continue; // the 7B-only baseline IS the energy floor
        }
        assert!(
            e1 <= ev.mean_energy_j + 1e-9,
            "ζ=1 should beat {label}: {e1} vs {}",
            ev.mean_energy_j
        );
    }
}

#[test]
fn dataset_roundtrip_preserves_fits() {
    // Fits computed from a CSV round-trip must match the originals —
    // guards the persistence path used by `repro-all`.
    let mut cfg = ExperimentConfig::default();
    cfg.grid_levels = vec![8, 128, 2048];
    let rows = family_rows(&cfg, 2, 5);
    let family = llama_family();
    let sets_a = fit_all(&family, &rows).unwrap();

    let csv = characterize::to_csv(&rows);
    let rows_b = characterize::from_csv(&csv).unwrap();
    let sets_b = fit_all(&family, &rows_b).unwrap();

    for (a, b) in sets_a.iter().zip(&sets_b) {
        for t in 0..3 {
            let rel = (a.energy.coefs[t] - b.energy.coefs[t]).abs()
                / a.energy.coefs[t].abs().max(1e-12);
            assert!(rel < 1e-6, "{} coef {t} drifted {rel}", a.model_id);
        }
    }
}

#[test]
fn stopping_rule_caps_and_converges_in_campaign() {
    let mut cfg = ExperimentConfig::default();
    cfg.grid_levels = vec![8, 2048];
    let campaign = Campaign::new(Cluster::new(Node::new(swing_node())), cfg);
    let spec = ecoserve::config::lookup("llama2-70b").unwrap();
    let mut rng = Rng::new(3);
    let cells = campaign.grid(&spec, 25, &mut rng);
    for c in &cells {
        assert!(c.trials.len() >= 3);
        assert!(c.trials.len() <= 25);
        // Long-running cells (big t_out) have sizeable absolute runtimes;
        // the 0.5 s tolerance usually converges quickly because variance
        // is low — but never beyond the cap.
    }
}
