//! Property-based integration tests (via `testkit::forall`): solver
//! optimality against brute force on random instances, scheduler
//! invariants, statistical-layer invariants.

use ecoserve::models::{AccuracyModel, ModelSet, Normalizer, Target, WorkloadModel};
use ecoserve::scheduler::{
    capacities, capacity_bounds, solve_exact_bucketed, solve_exact_caps, solve_greedy_caps,
    BucketedProblem, CapacityMode, CostMatrix,
};
use ecoserve::stats;
use ecoserve::testkit::{forall, Config};
use ecoserve::util::Rng;
use ecoserve::workload::Query;

fn random_costs(rng: &mut Rng, n_models: usize, n_queries: usize) -> CostMatrix {
    let costs = (0..n_models)
        .map(|_| (0..n_queries).map(|_| rng.range(-1.0, 1.0)).collect())
        .collect();
    CostMatrix::from_rows(costs)
}

/// Random paper-like model sets (bigger scale → pricier and, separately,
/// a random accuracy level).
fn random_sets(rng: &mut Rng, n_models: usize) -> Vec<ModelSet> {
    (0..n_models)
        .map(|i| {
            let scale = rng.range(0.5, 8.0);
            ModelSet {
                model_id: format!("m{i}"),
                energy: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::EnergyJ,
                    coefs: [0.5 * scale, 8.0 * scale, 0.003 * scale],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                runtime: WorkloadModel {
                    model_id: format!("m{i}"),
                    target: Target::RuntimeS,
                    coefs: [1e-3, 1e-2, 1e-6],
                    r2: 0.97,
                    f_stat: 1.0,
                    p_value: 0.0,
                    n_obs: 1,
                },
                accuracy: AccuracyModel::new(&format!("m{i}"), rng.range(40.0, 70.0)),
            }
        })
        .collect()
}

/// Brute-force optimum subject to (≥1, ≤cap) per model.
fn brute_force(costs: &CostMatrix, caps: &[usize]) -> f64 {
    fn rec(i: usize, assign: &mut Vec<usize>, caps: &[usize], c: &CostMatrix, best: &mut f64) {
        if i == assign.len() {
            let mut counts = vec![0usize; c.n_models];
            for &m in assign.iter() {
                counts[m] += 1;
            }
            if counts.iter().zip(caps).all(|(x, cap)| *x >= 1 && x <= cap) {
                let obj: f64 = assign.iter().enumerate().map(|(q, &m)| c.cost(m, q)).sum();
                if obj < *best {
                    *best = obj;
                }
            }
            return;
        }
        for m in 0..c.n_models {
            assign[i] = m;
            rec(i + 1, assign, caps, c, best);
        }
    }
    let mut best = f64::INFINITY;
    rec(0, &mut vec![0; costs.n_queries], caps, costs, &mut best);
    best
}

#[test]
fn prop_mcmf_is_optimal_on_random_instances() {
    forall(Config::default().cases(60), |rng| {
        let n_models = rng.int_range(2, 3) as usize;
        let n_queries = rng.int_range(n_models as i64, 7) as usize;
        let costs = random_costs(rng, n_models, n_queries);
        // Random feasible caps.
        let mut caps = vec![1usize; n_models];
        let mut extra = n_queries - n_models;
        while extra > 0 {
            caps[rng.index(n_models)] += 1;
            extra -= 1;
        }
        for c in caps.iter_mut() {
            *c += rng.index(3); // slack
        }
        let exact = solve_exact_caps(&costs, &caps).unwrap();
        let bf = brute_force(&costs, &caps);
        assert!(
            (exact.objective - bf).abs() < 1e-6,
            "mcmf {} vs brute {bf}",
            exact.objective
        );
        // Greedy is feasible and never better than exact.
        let greedy = solve_greedy_caps(&costs, &caps).unwrap();
        assert!(greedy.objective >= exact.objective - 1e-9);
        greedy.check_constraints(n_models).unwrap();
        exact.check_constraints(n_models).unwrap();
    });
}

#[test]
fn prop_capacities_always_partition_exactly() {
    forall(Config::default().cases(100), |rng| {
        let k = rng.int_range(1, 6) as usize;
        let n = rng.int_range(k as i64, 2000) as usize;
        // Random positive gammas normalized to 1.
        let raw: Vec<f64> = (0..k).map(|_| rng.range(0.01, 1.0)).collect();
        let sum: f64 = raw.iter().sum();
        let gammas: Vec<f64> = raw.iter().map(|g| g / sum).collect();
        let caps = capacities(&gammas, n);
        assert_eq!(caps.iter().sum::<usize>(), n, "caps must sum to n");
        assert!(caps.iter().all(|&c| c >= 1), "each model ≥ 1");
    });
}

#[test]
fn prop_cost_matrix_bounded_and_monotone_in_zeta() {
    forall(Config::default().cases(40), |rng| {
        let sets = random_sets(rng, 3);
        let queries: Vec<Query> = (0..20)
            .map(|id| Query {
                id,
                t_in: rng.int_range(1, 2048) as u32,
                t_out: rng.int_range(1, 4096) as u32,
            })
            .collect();
        let norm = Normalizer::from_workload(&sets, &queries);

        // Costs live in [−1, 1] at the extremes and are monotone in ζ for
        // each (k, q) pair.
        let c0 = CostMatrix::build(&sets, &norm, &queries, 0.0);
        let c5 = CostMatrix::build(&sets, &norm, &queries, 0.5);
        let c1 = CostMatrix::build(&sets, &norm, &queries, 1.0);
        for k in 0..3 {
            for i in 0..queries.len() {
                assert!((-1.0..=0.0).contains(&c0.cost(k, i)), "ζ=0 ⇒ −â ∈ [−1,0]");
                assert!((0.0..=1.0).contains(&c1.cost(k, i)), "ζ=1 ⇒ ê ∈ [0,1]");
                assert!(c0.cost(k, i) <= c5.cost(k, i) + 1e-12);
                assert!(c5.cost(k, i) <= c1.cost(k, i) + 1e-12);
            }
        }
    });
}

/// The shape-bucketed transportation reduction must be *exact*: on any
/// workload with duplicated shapes its objective equals the dense
/// per-query solver's to 1e-9, under both γ interpretations, and its
/// expansion is a feasible assignment whose recomputed dense objective
/// matches what it reported.
#[test]
fn prop_bucketed_matches_dense_on_duplicated_shapes() {
    forall(Config::default().cases(40), |rng| {
        let n_models = 2 + rng.index(3); // 2..=4
        let sets = random_sets(rng, n_models);

        // A small shape table guarantees heavy duplication.
        let n_shapes = 2 + rng.index(5); // 2..=6
        let table: Vec<(u32, u32)> = (0..n_shapes)
            .map(|_| {
                (
                    rng.int_range(1, 2048) as u32,
                    rng.int_range(1, 4096) as u32,
                )
            })
            .collect();
        let nq = n_models + rng.index(30); // ≥ one query per model
        let queries: Vec<Query> = (0..nq)
            .map(|id| {
                let (t_in, t_out) = table[rng.index(n_shapes)];
                Query {
                    id: id as u32,
                    t_in,
                    t_out,
                }
            })
            .collect();

        let norm = Normalizer::from_workload(&sets, &queries);
        let zeta = rng.range(0.0, 1.0);
        let dense = CostMatrix::build(&sets, &norm, &queries, zeta);
        let bp = BucketedProblem::build(&sets, &norm, &queries, zeta);
        assert!(bp.groups.n_shapes() <= n_shapes);
        assert_eq!(bp.n_queries(), nq);

        // Random positive gammas normalized to 1.
        let raw: Vec<f64> = (0..n_models).map(|_| rng.range(0.01, 1.0)).collect();
        let sum: f64 = raw.iter().sum();
        let gammas: Vec<f64> = raw.iter().map(|g| g / sum).collect();

        for mode in [CapacityMode::Eq3Only, CapacityMode::GammaHard] {
            let caps = capacity_bounds(mode, &gammas, nq);
            let d = solve_exact_caps(&dense, &caps).unwrap();
            let b = solve_exact_bucketed(&bp, &caps).unwrap();
            assert!(
                (d.objective - b.objective).abs() < 1e-9,
                "{mode:?}: bucketed {} vs dense {}",
                b.objective,
                d.objective
            );
            assert!(
                (b.objective_under(&dense) - b.objective).abs() < 1e-9,
                "{mode:?}: expansion objective drifts from reported"
            );
            b.check_constraints(n_models).unwrap();
            for (c, cap) in b.counts(n_models).iter().zip(&caps) {
                assert!(c <= cap, "{mode:?}: capacity violated");
            }
        }
    });
}

#[test]
fn prop_capacity_bounds_feasible_for_modes() {
    forall(Config::default().cases(60), |rng| {
        let k = rng.int_range(2, 5) as usize;
        let n = rng.int_range(k as i64, 600) as usize;
        let raw: Vec<f64> = (0..k).map(|_| rng.range(0.01, 1.0)).collect();
        let sum: f64 = raw.iter().sum();
        let gammas: Vec<f64> = raw.iter().map(|g| g / sum).collect();
        for mode in [CapacityMode::Eq3Only, CapacityMode::GammaHard] {
            let caps = capacity_bounds(mode, &gammas, n);
            assert_eq!(caps.len(), k);
            assert!(
                caps.iter().sum::<usize>() >= n,
                "{mode:?}: caps must cover the workload"
            );
        }
    });
}

#[test]
fn prop_ols_recovers_random_bilinear_models() {
    forall(Config::default().cases(30), |rng| {
        let a0 = rng.range(0.01, 2.0);
        let a1 = rng.range(0.1, 20.0);
        let a2 = rng.range(1e-5, 1e-2);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..60 {
            let ti = rng.range(8.0, 2048.0);
            let to = rng.range(8.0, 4096.0);
            xs.push(vec![ti, to, ti * to]);
            ys.push(a0 * ti + a1 * to + a2 * ti * to);
        }
        let fit = stats::ols_fit(&xs, &ys, &["a", "b", "ab"], false).unwrap();
        let rel = |got: f64, want: f64| (got - want).abs() / want;
        assert!(rel(fit.coefs[0].value, a0) < 1e-6);
        assert!(rel(fit.coefs[1].value, a1) < 1e-6);
        assert!(rel(fit.coefs[2].value, a2) < 1e-6);
        assert!(fit.r2 > 0.999999);
    });
}

#[test]
fn prop_anova_f_distribution_under_null() {
    // Under a pure-noise null, ANOVA p-values should be roughly uniform:
    // count how often p < 0.1 across seeds; expect ≈ 10%, tolerate wide.
    let mut hits = 0;
    let total = 120;
    for seed in 0..total {
        let mut rng = Rng::new(seed as u64);
        let mut obs = Vec::new();
        for a in [1u32, 2, 3] {
            for b in [1u32, 2, 3] {
                for _ in 0..4 {
                    obs.push(stats::Obs {
                        a,
                        b,
                        y: rng.normal(),
                    });
                }
            }
        }
        let t = stats::two_way(&obs, "A", "B").unwrap();
        if t.interaction.p_value < 0.1 {
            hits += 1;
        }
    }
    let rate = hits as f64 / total as f64;
    assert!(
        (0.02..=0.25).contains(&rate),
        "null rejection rate at p<0.1 should be ≈0.1, got {rate}"
    );
}
