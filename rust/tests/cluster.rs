//! Cluster-chaos properties of the replicated simulator: randomized
//! kill/drain/join schedules over a two-model, two-replica fleet must
//! (1) conserve work — every admitted query retires exactly once, even
//! when a kill requeues its in-flight batch mid-service; (2) stay byte-
//! deterministic — the same seed and failure script reproduce the v5
//! metrics artifact byte-for-byte under both engines; and (3) account
//! energy exactly — the per-replica node split partitions the run total
//! to 1e-9.
//!
//! The schedule generator only ever touches replica 1 (and joins a new
//! replica 2), so replica 0 of every model stays up for the whole run:
//! parked work is never stranded and the simulator's conservation bail
//! cannot fire by construction.

use ecoserve::models::Normalizer;
use ecoserve::sim::{
    EngineKind, FailureEvent, FailureKind, FailureScript, Hazard, PolicyKind, ResilienceConfig,
    SimConfig, SimMetrics, SimPolicy, Simulator,
};
use ecoserve::testkit::{forall, synthetic_pair, Config};
use ecoserve::util::{Json, Rng};
use ecoserve::workload::Query;

/// Arrival horizon for the generated workloads, seconds.
const HORIZON_S: f64 = 2.0;

fn chaos_workload(rng: &mut Rng, n: usize) -> (Vec<Query>, Vec<f64>) {
    let queries = (0..n)
        .map(|i| Query {
            id: i as u32,
            t_in: 8 + rng.index(64) as u32,
            t_out: 8 + rng.index(128) as u32,
        })
        .collect();
    let mut arrivals: Vec<f64> = (0..n).map(|_| rng.range(0.0, HORIZON_S)).collect();
    arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (queries, arrivals)
}

/// A random but always-valid schedule: per model, maybe kill or drain
/// replica 1 (possibly rejoining it later with a warm-up), and maybe
/// autoscale-join a fresh replica 2. Replica 0 is never targeted.
fn chaos_script(rng: &mut Rng, n_models: usize) -> FailureScript {
    let mut events = Vec::new();
    for k in 0..n_models {
        if rng.chance(0.8) {
            let t_down = rng.range(0.0, HORIZON_S);
            let kind = if rng.chance(0.5) {
                FailureKind::Kill
            } else {
                FailureKind::Drain
            };
            events.push(FailureEvent {
                t_s: t_down,
                model: k,
                replica: 1,
                kind,
            });
            if rng.chance(0.6) {
                events.push(FailureEvent {
                    t_s: t_down + rng.range(0.01, HORIZON_S),
                    model: k,
                    replica: 1,
                    kind: FailureKind::Join {
                        warmup_s: rng.range(0.0, 0.3),
                    },
                });
            }
        }
        if rng.chance(0.4) {
            events.push(FailureEvent {
                t_s: rng.range(0.0, HORIZON_S),
                model: k,
                replica: 2,
                kind: FailureKind::Join {
                    warmup_s: rng.range(0.0, 0.5),
                },
            });
        }
    }
    FailureScript::new(events).unwrap()
}

/// One chaos run: round-robin routing (so both models see traffic) over
/// a two-replica-per-model fleet under `script`.
fn chaos_run(
    sets: &[ecoserve::models::ModelSet],
    queries: &[Query],
    arrivals: &[f64],
    script: &FailureScript,
    engine: EngineKind,
    seed: u64,
    per_query: bool,
) -> SimMetrics {
    let cfg = SimConfig {
        max_batch: 3,
        max_wait_s: 0.05,
        slo_s: 30.0,
        per_query,
        engine,
        ..SimConfig::default()
    };
    let norm = Normalizer::from_workload(sets, queries);
    let mut policy =
        SimPolicy::new(PolicyKind::RoundRobin, sets, norm, 0.5, None, seed, None).unwrap();
    Simulator::new(sets, cfg)
        .labeled("chaos", seed, 0.5)
        .with_replicas(&[2, 2])
        .unwrap()
        .with_failures(script)
        .run(queries, arrivals, &mut policy)
        .unwrap()
}

/// Like [`chaos_run`], but with request-level survival armed: kills send
/// orphans into backoff-then-retry instead of instant requeueing.
fn resilient_run(
    sets: &[ecoserve::models::ModelSet],
    queries: &[Query],
    arrivals: &[f64],
    script: &FailureScript,
    engine: EngineKind,
    seed: u64,
    rc: ResilienceConfig,
) -> SimMetrics {
    let cfg = SimConfig {
        max_batch: 3,
        max_wait_s: 0.05,
        slo_s: 30.0,
        engine,
        ..SimConfig::default()
    };
    let norm = Normalizer::from_workload(sets, queries);
    let mut policy =
        SimPolicy::new(PolicyKind::RoundRobin, sets, norm, 0.5, None, seed, None).unwrap();
    Simulator::new(sets, cfg)
        .labeled("chaos", seed, 0.5)
        .with_replicas(&[2, 2])
        .unwrap()
        .with_failures(script)
        .with_resilience(rc)
        .unwrap()
        .run(queries, arrivals, &mut policy)
        .unwrap()
}

/// A small deterministic run for the drain-vs-kill race-edge tests:
/// eight fixed-shape queries on a paced arrival comb, two replicas per
/// model, round-robin routing.
fn edge_run(
    sets: &[ecoserve::models::ModelSet],
    script: &FailureScript,
    engine: EngineKind,
) -> anyhow::Result<SimMetrics> {
    let queries: Vec<Query> = (0..8)
        .map(|i| Query {
            id: i,
            t_in: 32,
            t_out: 64,
        })
        .collect();
    let arrivals: Vec<f64> = (0..8).map(|i| 0.05 * i as f64).collect();
    let cfg = SimConfig {
        max_batch: 2,
        max_wait_s: 0.02,
        slo_s: 30.0,
        per_query: true,
        engine,
        ..SimConfig::default()
    };
    let norm = Normalizer::from_workload(sets, &queries);
    let mut policy =
        SimPolicy::new(PolicyKind::RoundRobin, sets, norm, 0.5, None, 9, None).unwrap();
    Simulator::new(sets, cfg)
        .labeled("edge", 9, 0.5)
        .with_replicas(&[2, 2])
        .unwrap()
        .with_failures(script)
        .run(&queries, &arrivals, &mut policy)
}

#[test]
fn chaos_conserves_every_query() {
    let sets = synthetic_pair();
    forall(Config::default().cases(24), |rng| {
        let n = 16 + rng.index(64);
        let (queries, arrivals) = chaos_workload(&mut rng.fork(1), n);
        let script = chaos_script(&mut rng.fork(2), sets.len());
        let seed = rng.next_u64();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let m = chaos_run(&sets, &queries, &arrivals, &script, engine, seed, true);
            // Every admitted query retires exactly once: the artifact
            // totals, the per-replica split, and the per-query outcome
            // ids all agree on exactly the submitted id set.
            assert_eq!(m.n_queries as usize, n);
            let node_queries: u64 = m.nodes.iter().map(|s| s.queries).sum();
            assert_eq!(node_queries, m.n_queries);
            let node_requeued: u64 = m.nodes.iter().map(|s| s.requeued).sum();
            assert_eq!(node_requeued, m.n_requeued);
            let mut ids: Vec<u64> = m
                .outcomes
                .as_ref()
                .expect("per-query outcomes retained")
                .iter()
                .map(|o| o.id)
                .collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>());
            if script.is_empty() {
                assert_eq!(m.scenario, "none");
            } else {
                assert_eq!(m.scenario, script.label());
            }
        }
    });
}

#[test]
fn chaos_runs_are_byte_deterministic() {
    let sets = synthetic_pair();
    forall(Config::default().cases(12), |rng| {
        let n = 16 + rng.index(48);
        let (queries, arrivals) = chaos_workload(&mut rng.fork(1), n);
        let script = chaos_script(&mut rng.fork(2), sets.len());
        let seed = rng.next_u64();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let a = chaos_run(&sets, &queries, &arrivals, &script, engine, seed, false);
            let b = chaos_run(&sets, &queries, &arrivals, &script, engine, seed, false);
            assert_eq!(
                a.to_json().to_string_pretty(),
                b.to_json().to_string_pretty(),
                "engine {} replay diverged",
                engine.label()
            );
        }
    });
}

#[test]
fn drain_landing_mid_iteration_still_retires_everything() {
    let sets = synthetic_pair();
    // Replica 0 of model 0 is mid-service when the drain lands (first
    // batch starts by t=0.02 via the wait timeout and runs well past
    // t=0.06): queued work must finish on the drained engine, nothing
    // requeues, and later arrivals fall to the sibling replica.
    let script = FailureScript::new(vec![FailureEvent {
        t_s: 0.06,
        model: 0,
        replica: 0,
        kind: FailureKind::Drain,
    }])
    .unwrap();
    for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
        let m = edge_run(&sets, &script, engine).unwrap();
        assert_eq!(m.n_queries, 8, "engine {}", engine.label());
        assert_eq!(m.n_requeued, 0, "drain must never abort work");
        let mut ids: Vec<u64> = m
            .outcomes
            .as_ref()
            .expect("per-query outcomes retained")
            .iter()
            .map(|o| o.id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
    }
}

#[test]
fn kill_of_an_already_drained_replica_is_rejected() {
    let sets = synthetic_pair();
    // A drain marks the replica down immediately (it only finishes what
    // it already holds), so a later kill of the same replica is a script
    // contradiction — both engines refuse it by name instead of
    // double-counting the downtime interval.
    let script = FailureScript::new(vec![
        FailureEvent {
            t_s: 0.06,
            model: 0,
            replica: 1,
            kind: FailureKind::Drain,
        },
        FailureEvent {
            t_s: 0.5,
            model: 0,
            replica: 1,
            kind: FailureKind::Kill,
        },
    ])
    .unwrap();
    for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
        let err = edge_run(&sets, &script, engine).unwrap_err().to_string();
        assert!(err.contains("already down"), "engine {}: {err}", engine.label());
        assert!(err.contains("kill"), "{err}");
    }
}

#[test]
fn join_warmup_outliving_the_run_still_settles_downtime() {
    let sets = synthetic_pair();
    // The rejoining replica activates 600 s after a 2 s workload: the
    // warm-up outlives every completion, yet the downtime interval still
    // closes exactly at the activation instant — never left dangling at
    // whatever the last completion happened to be.
    let script = FailureScript::new(vec![
        FailureEvent {
            t_s: 0.1,
            model: 1,
            replica: 1,
            kind: FailureKind::Kill,
        },
        FailureEvent {
            t_s: 0.2,
            model: 1,
            replica: 1,
            kind: FailureKind::Join { warmup_s: 600.0 },
        },
    ])
    .unwrap();
    let mut downtimes = Vec::new();
    for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
        let m = edge_run(&sets, &script, engine).unwrap();
        assert_eq!(m.n_queries, 8, "engine {}", engine.label());
        assert!(m.makespan_s < 600.0, "makespan {}", m.makespan_s);
        let nd = m
            .nodes
            .iter()
            .find(|nd| nd.model_id == sets[1].model_id && nd.replica == 1)
            .expect("rejoined replica keeps its node row");
        // Down from the kill at 0.1 s to activation at 0.2 + 600 s.
        assert!(
            (nd.downtime_s - 600.1).abs() < 1e-6,
            "engine {}: downtime {}",
            engine.label(),
            nd.downtime_s
        );
        downtimes.push(nd.downtime_s);
    }
    // Downtime is a pure function of the script — engine-independent.
    assert_eq!(downtimes[0], downtimes[1]);
}

#[test]
fn resilient_chaos_conserves_and_partitions_v6_counters() {
    let sets = synthetic_pair();
    forall(Config::default().cases(12), |rng| {
        let n = 16 + rng.index(48);
        let (queries, arrivals) = chaos_workload(&mut rng.fork(1), n);
        let script = chaos_script(&mut rng.fork(2), sets.len());
        let seed = rng.next_u64();
        let rc = ResilienceConfig {
            retry_budget: 2,
            breaker_threshold: 1,
            hedge_after_s: Some(0.25),
            ..ResilienceConfig::default()
        };
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let m = resilient_run(&sets, &queries, &arrivals, &script, engine, seed, rc);
            // Conservation under survival semantics: every admitted
            // query either completes exactly once or exhausts its retry
            // budget — never both, never neither.
            assert_eq!(
                m.n_queries + m.n_failed,
                n as u64,
                "engine {}",
                engine.label()
            );
            // The v6 artifact's run totals partition exactly over the
            // per-replica node rows (integer counters, so byte-exact
            // through the JSON round-trip).
            let v = Json::parse(&m.to_json().to_string_pretty()).unwrap();
            let nodes = v.get("nodes").as_array().unwrap();
            let sum = |key: &str| -> f64 {
                nodes.iter().map(|nd| nd.get(key).as_f64().unwrap()).sum()
            };
            assert_eq!(sum("retries"), v.get("n_retries").as_f64().unwrap());
            assert_eq!(sum("hedges"), v.get("n_hedges").as_f64().unwrap());
            assert_eq!(
                sum("breaker_trips"),
                v.get("n_breaker_trips").as_f64().unwrap()
            );
            assert_eq!(sum("queries") as u64, m.n_queries);
            // Availability folds failures into the denominator, so it
            // can never exceed raw SLO attainment.
            assert!(m.availability <= m.slo_attainment + 1e-12);
        }
    });
}

#[test]
fn hazard_scripts_replay_byte_identically_under_both_engines() {
    let sets = synthetic_pair();
    let h = Hazard::parse("mtbf:0.5:0.1").unwrap();
    let (queries, arrivals) = chaos_workload(&mut Rng::new(11), 40);
    for hazard_seed in [1u64, 2, 3] {
        let script = h.generate(&[2, 2], HORIZON_S + 1.0, hazard_seed).unwrap();
        // Generation is a pure function of (counts, horizon, seed)…
        let again = h.generate(&[2, 2], HORIZON_S + 1.0, hazard_seed).unwrap();
        assert_eq!(script, again);
        // …and replaying the drawn script is byte-stable per engine.
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let rc = ResilienceConfig::default();
            let a = resilient_run(&sets, &queries, &arrivals, &script, engine, 7, rc);
            let b = resilient_run(&sets, &queries, &arrivals, &script, engine, 7, rc);
            assert_eq!(
                a.to_json().to_string_pretty(),
                b.to_json().to_string_pretty(),
                "engine {} hazard replay diverged",
                engine.label()
            );
            assert_eq!(a.scenario, "mtbf:0.5:0.1");
            assert_eq!(a.n_queries + a.n_failed, 40);
        }
    }
}

#[test]
fn chaos_energy_partitions_across_replicas() {
    let sets = synthetic_pair();
    forall(Config::default().cases(24), |rng| {
        let n = 16 + rng.index(64);
        let (queries, arrivals) = chaos_workload(&mut rng.fork(1), n);
        let script = chaos_script(&mut rng.fork(2), sets.len());
        let seed = rng.next_u64();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let m = chaos_run(&sets, &queries, &arrivals, &script, engine, seed, false);
            let node_energy: f64 = m.nodes.iter().map(|s| s.energy_j).sum();
            assert!(
                (node_energy - m.total_energy_j).abs()
                    <= 1e-9 * m.total_energy_j.abs().max(1.0),
                "per-replica energy {} != run total {} (engine {})",
                node_energy,
                m.total_energy_j,
                engine.label()
            );
            for s in &m.nodes {
                assert!(s.energy_j >= 0.0 && s.downtime_s >= 0.0);
                // Decode is the complement: prefill can never exceed the
                // node total.
                assert!(s.prefill_j >= 0.0 && s.prefill_j <= s.energy_j + 1e-9);
            }
            assert!(
                (m.prefill_energy_j + m.decode_energy_j - m.total_energy_j).abs()
                    <= 1e-9 * m.total_energy_j.max(1.0)
            );
        }
    });
}
