//! Cluster-chaos properties of the replicated simulator: randomized
//! kill/drain/join schedules over a two-model, two-replica fleet must
//! (1) conserve work — every admitted query retires exactly once, even
//! when a kill requeues its in-flight batch mid-service; (2) stay byte-
//! deterministic — the same seed and failure script reproduce the v5
//! metrics artifact byte-for-byte under both engines; and (3) account
//! energy exactly — the per-replica node split partitions the run total
//! to 1e-9.
//!
//! The schedule generator only ever touches replica 1 (and joins a new
//! replica 2), so replica 0 of every model stays up for the whole run:
//! parked work is never stranded and the simulator's conservation bail
//! cannot fire by construction.

use ecoserve::models::Normalizer;
use ecoserve::sim::{
    EngineKind, FailureEvent, FailureKind, FailureScript, PolicyKind, SimConfig, SimMetrics,
    SimPolicy, Simulator,
};
use ecoserve::testkit::{forall, synthetic_pair, Config};
use ecoserve::util::Rng;
use ecoserve::workload::Query;

/// Arrival horizon for the generated workloads, seconds.
const HORIZON_S: f64 = 2.0;

fn chaos_workload(rng: &mut Rng, n: usize) -> (Vec<Query>, Vec<f64>) {
    let queries = (0..n)
        .map(|i| Query {
            id: i as u32,
            t_in: 8 + rng.index(64) as u32,
            t_out: 8 + rng.index(128) as u32,
        })
        .collect();
    let mut arrivals: Vec<f64> = (0..n).map(|_| rng.range(0.0, HORIZON_S)).collect();
    arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (queries, arrivals)
}

/// A random but always-valid schedule: per model, maybe kill or drain
/// replica 1 (possibly rejoining it later with a warm-up), and maybe
/// autoscale-join a fresh replica 2. Replica 0 is never targeted.
fn chaos_script(rng: &mut Rng, n_models: usize) -> FailureScript {
    let mut events = Vec::new();
    for k in 0..n_models {
        if rng.chance(0.8) {
            let t_down = rng.range(0.0, HORIZON_S);
            let kind = if rng.chance(0.5) {
                FailureKind::Kill
            } else {
                FailureKind::Drain
            };
            events.push(FailureEvent {
                t_s: t_down,
                model: k,
                replica: 1,
                kind,
            });
            if rng.chance(0.6) {
                events.push(FailureEvent {
                    t_s: t_down + rng.range(0.01, HORIZON_S),
                    model: k,
                    replica: 1,
                    kind: FailureKind::Join {
                        warmup_s: rng.range(0.0, 0.3),
                    },
                });
            }
        }
        if rng.chance(0.4) {
            events.push(FailureEvent {
                t_s: rng.range(0.0, HORIZON_S),
                model: k,
                replica: 2,
                kind: FailureKind::Join {
                    warmup_s: rng.range(0.0, 0.5),
                },
            });
        }
    }
    FailureScript::new(events).unwrap()
}

/// One chaos run: round-robin routing (so both models see traffic) over
/// a two-replica-per-model fleet under `script`.
fn chaos_run(
    sets: &[ecoserve::models::ModelSet],
    queries: &[Query],
    arrivals: &[f64],
    script: &FailureScript,
    engine: EngineKind,
    seed: u64,
    per_query: bool,
) -> SimMetrics {
    let cfg = SimConfig {
        max_batch: 3,
        max_wait_s: 0.05,
        slo_s: 30.0,
        per_query,
        engine,
        ..SimConfig::default()
    };
    let norm = Normalizer::from_workload(sets, queries);
    let mut policy =
        SimPolicy::new(PolicyKind::RoundRobin, sets, norm, 0.5, None, seed, None).unwrap();
    Simulator::new(sets, cfg)
        .labeled("chaos", seed, 0.5)
        .with_replicas(&[2, 2])
        .unwrap()
        .with_failures(script)
        .run(queries, arrivals, &mut policy)
        .unwrap()
}

#[test]
fn chaos_conserves_every_query() {
    let sets = synthetic_pair();
    forall(Config::default().cases(24), |rng| {
        let n = 16 + rng.index(64);
        let (queries, arrivals) = chaos_workload(&mut rng.fork(1), n);
        let script = chaos_script(&mut rng.fork(2), sets.len());
        let seed = rng.next_u64();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let m = chaos_run(&sets, &queries, &arrivals, &script, engine, seed, true);
            // Every admitted query retires exactly once: the artifact
            // totals, the per-replica split, and the per-query outcome
            // ids all agree on exactly the submitted id set.
            assert_eq!(m.n_queries as usize, n);
            let node_queries: u64 = m.nodes.iter().map(|s| s.queries).sum();
            assert_eq!(node_queries, m.n_queries);
            let node_requeued: u64 = m.nodes.iter().map(|s| s.requeued).sum();
            assert_eq!(node_requeued, m.n_requeued);
            let mut ids: Vec<u64> = m
                .outcomes
                .as_ref()
                .expect("per-query outcomes retained")
                .iter()
                .map(|o| o.id)
                .collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>());
            if script.is_empty() {
                assert_eq!(m.scenario, "none");
            } else {
                assert_eq!(m.scenario, script.label());
            }
        }
    });
}

#[test]
fn chaos_runs_are_byte_deterministic() {
    let sets = synthetic_pair();
    forall(Config::default().cases(12), |rng| {
        let n = 16 + rng.index(48);
        let (queries, arrivals) = chaos_workload(&mut rng.fork(1), n);
        let script = chaos_script(&mut rng.fork(2), sets.len());
        let seed = rng.next_u64();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let a = chaos_run(&sets, &queries, &arrivals, &script, engine, seed, false);
            let b = chaos_run(&sets, &queries, &arrivals, &script, engine, seed, false);
            assert_eq!(
                a.to_json().to_string_pretty(),
                b.to_json().to_string_pretty(),
                "engine {} replay diverged",
                engine.label()
            );
        }
    });
}

#[test]
fn chaos_energy_partitions_across_replicas() {
    let sets = synthetic_pair();
    forall(Config::default().cases(24), |rng| {
        let n = 16 + rng.index(64);
        let (queries, arrivals) = chaos_workload(&mut rng.fork(1), n);
        let script = chaos_script(&mut rng.fork(2), sets.len());
        let seed = rng.next_u64();
        for engine in [EngineKind::Lockstep, EngineKind::Continuous] {
            let m = chaos_run(&sets, &queries, &arrivals, &script, engine, seed, false);
            let node_energy: f64 = m.nodes.iter().map(|s| s.energy_j).sum();
            assert!(
                (node_energy - m.total_energy_j).abs()
                    <= 1e-9 * m.total_energy_j.abs().max(1.0),
                "per-replica energy {} != run total {} (engine {})",
                node_energy,
                m.total_energy_j,
                engine.label()
            );
            for s in &m.nodes {
                assert!(s.energy_j >= 0.0 && s.downtime_s >= 0.0);
                // Decode is the complement: prefill can never exceed the
                // node total.
                assert!(s.prefill_j >= 0.0 && s.prefill_j <= s.energy_j + 1e-9);
            }
            assert!(
                (m.prefill_energy_j + m.decode_energy_j - m.total_energy_j).abs()
                    <= 1e-9 * m.total_energy_j.max(1.0)
            );
        }
    });
}
