//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate wraps the native XLA/PJRT toolchain, which the build
//! container does not ship. This stub exposes the exact API surface the
//! `ecoserve` runtime layer compiles against; every execution path returns
//! a descriptive [`Error`] at runtime instead of running a computation.
//! All callers gate PJRT work on `artifacts/manifest.json` existing, so
//! tests and benches skip cleanly without the native backend.
//!
//! Like the real client (which is `Rc`-based), [`PjRtClient`] is `!Send`:
//! the coordinator's one-engine-host-thread discipline still typechecks.

use std::fmt;
use std::marker::PhantomData;
use std::path::Path;
use std::rc::Rc;

/// Error type matching the real crate's `anyhow`-compatible surface.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} requires the native PJRT backend, which is not \
         available in this build (run `make artifacts` on a machine with the \
         XLA toolchain)"
    ))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// Host-side tensor value. The stub records only the logical shape.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            dims: vec![values.len() as i64],
        }
    }

    /// Reinterpret the literal with new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let old: i64 = self.dims.iter().product();
        let new: i64 = dims.iter().product();
        if old != new {
            return Err(Error(format!(
                "xla stub: reshape {:?} -> {:?} changes element count",
                self.dims, dims
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
        })
    }

    /// Copy the elements out to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Destructure a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Destructure a 1-tuple literal into its single element.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }
}

/// Parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO-text artifact.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error(format!(
            "xla stub: cannot parse HLO text {} without the native backend",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _not_send: PhantomData<Rc<()>>,
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; one result buffer list per device.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. `Rc`-based like the real crate, hence `!Send`.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _rc: Rc<()>,
}

impl PjRtClient {
    /// Connect to the host CPU PJRT plugin.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation for this client's devices.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
